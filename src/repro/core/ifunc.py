"""ifuncs: injected functions — code that travels with the message.

Source side, an :class:`IFunc` couples an entry function (a pure JAX
function) with its fat-bitcode archive (``jax.export`` blobs for every
toolchain target, Sec. III-C) and its dependency list (Sec. III-C ``.deps``).
Target side, a :class:`PE` (processing element) polls its endpoint, installs
arriving code (extract slice -> deserialize -> target-side JIT -> digest
cache) and invokes it.

ABI — how the runtime and injected code meet
--------------------------------------------
The paper's ifunc entry is ``main(payload, payload_size, target_ptr)`` and
may call UCX itself (via remote dynamic linking) to recursively re-inject
itself.  An XLA executable cannot call back into the transport mid-flight,
so the TPU-idiomatic rendering keeps the *decision logic in the shipped
code* and leaves only a fixed, function-agnostic action protocol in the
runtime (the moral equivalent of the UCX API the paper's ifuncs link
against):

* ``update`` ABI — ``entry(payload, region) -> new_region``.  The runtime
  stores the result back into the named memory region (TSI's counter).
* ``xrdma`` ABI — ``entry(payload, *linked_deps) -> i64[ACTION_WIDTH]``
  action vector::

      [action, dst, plen, p0 .. p7]

  ``action``: 0 DONE | 1 FORWARD (re-inject *this same ifunc*, code and
  all, to peer ``dst`` with payload ``p[:plen]``) | 2 RETURN (send the
  ifunc named by the ``returns:`` dep to ``dst``) | 3 SPAWN (send the
  ifunc named by the ``spawn:`` dep — "generate new code") | 4 NOP
  (no action; skipped by the runtime) | 5 PUBLISH (re-publish *this same
  ifunc* to peer ``dst`` under a fresh propagation hop header — ``p0`` is
  the hop ttl, ``p[1:plen]`` the published payload; this is how shipped
  code recursively propagates itself, Sec. I).
* ``propagate`` ABI — ``entry(payload, region, *deps) -> (new_region,
  actions)``: one entry both folds into its linked region (like
  ``update``) *and* emits action rows (like ``xrdma``).  Under the
  batched runtime the region fold is the same masked ``lax.scan`` as
  ``update`` — which is exactly what a tree reduction needs: child
  partials fold into the accumulator in one dispatch, and the row whose
  fold completes the subtree emits the upward FORWARD.

  An xrdma entry may instead return an ``(R, W)`` i32 *matrix* of action
  rows; the runtime applies the rows in order.  ``W`` only has to satisfy
  ``W >= 3 + plen`` for every row — rows are self-describing via their
  ``plen`` field, so one rectangular matrix carries ragged payloads.  NOP
  rows are how statically-shaped shipped code emits a *variable* number
  of actions: the Gatherer, for example, returns one potential FORWARD
  row per peer shard plus one RETURN row, and NOPs the rows it does not
  need this invocation.

  Local recursion — the paper's "ifunc calls itself recursively" when the
  next pointer is local — happens *inside* the shipped code as a
  ``lax.while_loop``: the blob chases until the frontier leaves its shard,
  then emits FORWARD.  One network action per locality break, exactly the
  paper's DAPC behaviour.

Dependency tags (the wire ``DEPS`` list, Sec. III-C):

* ``abi:<update|xrdma|pure>`` — invoke convention.
* ``region:<name>`` — link the PE's registered memory region as an argument.
* ``cap:<name>``    — link a host capability (small constant array, e.g.
  shard metadata) as an argument.
* ``returns:<ifunc>`` / ``spawn:<ifunc>`` — ifunc types this code may emit;
  resolved through the PE's source registry / toolchain at action time.
"""

from __future__ import annotations

import hashlib
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .bitcode import DEFAULT_TOOLCHAIN_TARGETS, FatBitcode, platform_of
from .cache import CachedExecutable, SenderCache, TargetCodeCache
from .dataplane import DataPlaneConfig, SlabLayout
from .frame import (
    Frame,
    FrameFlags,
    FrameKind,
    HopHeader,
    ProtocolError,
    coalesce,
    pack_hop,
    pack_rndv,
    peek_header,
    rndv_region,
    split_hop,
    split_payloads,
    unpack,
    unpack_rndv,
)
from .propagate import PropagationConfig, tree_children
from .transport import EndpointDead, Fabric, RegionWrite

ACTION_WIDTH = 11  # [action, dst, plen, p0..p7]
A_DONE, A_FORWARD, A_RETURN, A_SPAWN, A_NOP, A_PUBLISH = 0, 1, 2, 3, 4, 5

# rendezvous staging ring depth: outstanding staged RETURN payloads per PE
# before the oldest registration is reclaimed (bounds pinned memory the way
# a real transport bounds its rendezvous buffer pool)
RNDV_STAGING_DEPTH = 1024


class ISAMismatch(RuntimeError):
    """Binary ifunc landed on a PE whose triple it was not compiled for."""


# ----------------------------------------------------------------- source
@dataclass
class IFunc:
    """Source-side handle: name + fat-bitcode + deps (paper Fig. 1 register)."""

    name: str
    fat: FatBitcode
    deps: tuple[str, ...]
    abi: str
    payload_aval: jax.ShapeDtypeStruct
    kind: FrameKind = FrameKind.BITCODE
    # Optional zero-copy layout for RETURN-type ifuncs: lets a sender map
    # this ifunc's payload onto one-sided slab writes instead of a frame.
    # Sender-side only — never travels on the wire, never affects digest.
    slab: SlabLayout | None = None

    @property
    def code_bytes(self) -> bytes:
        return self.fat.to_bytes()

    @property
    def digest(self) -> bytes:
        import hashlib

        return hashlib.sha256(self.code_bytes).digest()

    @classmethod
    def build(
        cls,
        name: str,
        fn: Callable[..., Any],
        payload_aval: jax.ShapeDtypeStruct,
        dep_avals: Sequence[jax.ShapeDtypeStruct] = (),
        deps: Sequence[str] = (),
        abi: str = "pure",
        targets: Sequence[str] = DEFAULT_TOOLCHAIN_TARGETS,
        kind: FrameKind = FrameKind.BITCODE,
        fn_by_platform=None,
        slab: SlabLayout | None = None,
    ) -> "IFunc":
        """Run the Three-Chains toolchain: cross-compile ``fn`` for every
        target triple into a fat-bitcode archive.

        ``kind=BINARY`` models Sec. III-B: the archive holds exactly one
        slice (the source machine's own triple) and the target will refuse
        a triple mismatch instead of re-lowering.  ``fn_by_platform``
        optionally swaps the entry per platform (see FatBitcode.build).
        """
        if kind == FrameKind.BINARY and len(targets) != 1:
            raise ValueError("binary ifuncs are single-triple by definition")
        fat = FatBitcode.build(
            fn, (payload_aval, *dep_avals), targets=targets,
            fn_by_platform=fn_by_platform,
        )
        wire_deps = (f"abi:{abi}", *deps)
        return cls(
            name=name,
            fat=fat,
            deps=wire_deps,
            abi=abi,
            payload_aval=payload_aval,
            kind=kind,
            slab=slab,
        )

    def make_frame(self, payload: bytes, seq: int = 0) -> Frame:
        return Frame(
            kind=self.kind,
            name=self.name,
            payload=payload,
            code=self.code_bytes,
            deps=self.deps,
            digest=self.digest,
            seq=seq,
        )


class Toolchain:
    """The shared filesystem of toolchain artifacts (paper Fig. 1: generated
    files 'placed in a directory that can be located by Three-Chains').

    Any PE may *register as a sender* from here — that is how a server that
    received a Chaser can emit a ReturnResult it never received over the
    wire, just as the paper's SPMD app binaries can register any ifunc
    library present on their local disk.  What is NOT pre-deployed is the
    target-side executable: code still travels in frames and installs via
    the cache protocol.
    """

    def __init__(self) -> None:
        self._artifacts: dict[str, IFunc] = {}

    def publish(self, ifunc: IFunc) -> IFunc:
        self._artifacts[ifunc.name] = ifunc
        return ifunc

    def lookup(self, name: str) -> IFunc:
        return self._artifacts[name]

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._artifacts))


# ----------------------------------------------------------------- target
@dataclass
class PEStats:
    msgs: int = 0
    ifunc_installs: int = 0
    invokes: int = 0  # XLA dispatches (a batched dispatch counts once)
    batched_invokes: int = 0  # dispatches that retired >1 payload
    invoked_payloads: int = 0  # payloads retired across all dispatches
    forwards: int = 0
    returns: int = 0
    spawns: int = 0
    sends: int = 0  # frames this PE PUT on the wire (any kind)
    code_sends: int = 0  # of those, frames that carried code bytes
    zerocopy_returns: int = 0  # RETURNs that went one-sided (no frame/dispatch)
    rndv_returns: int = 0  # RETURNs that went descriptor + GET
    am_handled: int = 0
    flushes: int = 0
    # --- recursive propagation (PUBLISH hops) ---
    publishes: int = 0  # hop frames sent (root fan-out + re-publishes)
    publish_handled: int = 0  # publishes accepted (installed/invoked) here
    publish_dupes: int = 0  # re-delivered publishes dropped by the dedup key
    publish_refused_ttl: int = 0  # arrived with ttl already expired (loud)
    publish_refused_cycle: int = 0  # own index on the visited path (loud)
    publish_refused_digest: int = 0  # code bytes != header digest (poisoned)
    publish_stopped_ttl: int = 0  # had children but no hop budget left
    publish_send_failures: int = 0  # child endpoint dead at re-publish time
    jit_ms_total: float = 0.0

    def as_dict(self) -> dict[str, float]:
        d = self.__dict__.copy()
        d["jit_ms_total"] = round(self.jit_ms_total, 3)
        return d


class PE:
    """A processing element: endpoint + ifunc runtime + caches + local state.

    ``triple`` models the ISA/uarch (hosts are ``cpu-host`` Xeons, DPUs are
    ``cpu-bf2`` BlueField Arm cores, A64FX nodes ``cpu-a64fx``); on this
    container all execute on the CPU backend, but triple *mismatch logic* is
    real: binary ifuncs require an exact triple, fat-bitcode falls back by
    platform and re-optimizes locally (Sec. III-C).
    """

    def __init__(
        self,
        name: str,
        fabric: Fabric,
        triple: str = "cpu-host",
        toolchain: Toolchain | None = None,
        peers: Sequence[str] = (),
    ) -> None:
        platform_of(triple)  # validate
        self.name = name
        self.triple = triple
        self.fabric = fabric
        self.endpoint = fabric.connect(name)
        self.toolchain = toolchain
        self.peers: list[str] = list(peers)
        self.target_cache = TargetCodeCache()
        self.sender_cache = SenderCache()
        self.source_registry: dict[str, IFunc] = {}
        self.am_table: dict[str, Callable[["PE", bytes], None]] = {}
        self.caps: dict[str, np.ndarray] = {}
        self.completed: list[np.ndarray] = []
        self.stats = PEStats()
        self.caching_enabled = True  # benchmark switch: uncached mode
        self.batching = False  # batched runtime: coalesced sends + grouped polls
        self.dataplane = DataPlaneConfig()  # protocol selection (default: framed)
        self.propagation = PropagationConfig()  # tree multicast policy
        self._seq = 0
        self._region_dev: dict[str, tuple[int, jax.Array]] = {}
        self._sendq: dict[str, list[Frame]] = {}  # per-destination pending frames
        self._regionq: dict[str, list[RegionWrite]] = {}  # pending one-sided writes
        self._rndv_tokens: deque[str] = deque()  # staged rendezvous regions (ring)
        self._rndv_seq = 0
        self._pub_seq = 0  # publish ids minted by this PE as a tree root
        self._seen_pubs: set[tuple[bytes, int, int]] = set()  # publish dedup

    # --- local state ------------------------------------------------------
    def register_region(self, name: str, arr: np.ndarray) -> None:
        self.endpoint.register_region(name, arr)

    def region(self, name: str) -> np.ndarray:
        return self.endpoint.regions[name]

    def _region_device(self, name: str) -> jax.Array:
        """Device-resident view of a region, cached until the region is
        rewritten (read-mostly shards stay resident, like RDMA-registered
        memory staying pinned).  Versioning lives on the endpoint so that
        *remote* one-sided writes (zero-copy RETURNs landing in a slab)
        also invalidate the device mirror — otherwise a framed fold could
        read a stale snapshot and overwrite bytes the fabric just wrote."""
        ver = self.endpoint.region_ver.get(name, 0)
        hit = self._region_dev.get(name)
        if hit is not None and hit[0] == ver:
            return hit[1]
        dev = jax.device_put(self.endpoint.regions[name])
        self._region_dev[name] = (ver, dev)
        return dev

    def _write_region(self, name: str, value: np.ndarray) -> None:
        np.copyto(self.endpoint.regions[name], value)
        self.endpoint.touch_region(name)

    def register_cap(self, name: str, arr: np.ndarray) -> None:
        self.caps[name] = np.asarray(arr)

    # --- source side --------------------------------------------------------
    def register_source(self, ifunc: IFunc) -> IFunc:
        self.source_registry[ifunc.name] = ifunc
        return ifunc

    def _resolve_source(self, name: str) -> IFunc:
        got = self.source_registry.get(name)
        if got is None:
            if self.toolchain is None:
                raise ProtocolError(f"{self.name}: no source artifact for {name!r}")
            got = self.register_source(self.toolchain.lookup(name))
        return got

    def send_ifunc(self, dst: str, name: str, payload: np.ndarray | bytes) -> int:
        """Create and PUT an ifunc message; returns wire bytes sent."""
        ifunc = self._resolve_source(name)
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        self._seq += 1
        frame = ifunc.make_frame(pay, seq=self._seq)
        return self._put_frame(dst, frame)

    def send_am(self, dst: str, name: str, payload: np.ndarray | bytes) -> int:
        """Active Message baseline: payload-only frame, handler pre-deployed."""
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        self._seq += 1
        frame = Frame(kind=FrameKind.ACTIVE_MESSAGE, name=name, payload=pay, seq=self._seq)
        return self._put_frame(dst, frame)

    # --- recursive propagation: source side ---------------------------------
    def publish_ifunc(
        self,
        name: str,
        payload: np.ndarray | bytes = b"",
        *,
        ttl: int | None = None,
        config: PropagationConfig | None = None,
    ) -> list[str]:
        """Publish an ifunc down this PE's spanning tree (paper Sec. I:
        code that "recursively propagate[s] itself to other remote
        machines").

        Sends one PUBLISH hop frame to each of this PE's *tree children*
        only — O(log n) for the binomial default — and every child that
        installs the code re-publishes it to its own children, so coverage
        reaches all n peers without the root sending n frames.  An empty
        ``payload`` is a pure code distribution (install + re-publish, no
        invoke); a non-empty payload is invoked at every covered PE (the
        broadcast the multi-hop collectives build on).  Returns the peer
        names actually sent to.
        """
        cfg = config or self.propagation
        ifunc = self._resolve_source(name)
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        me = self.peer_index(self.name)
        self._pub_seq += 1
        hop = HopHeader(
            ttl=ttl if ttl is not None else cfg.ttl,
            root=me,
            pub_id=self._pub_seq,
            path=(me,),
            k=cfg.k_code,
        )
        return self._publish_to_children(
            hop, ifunc.kind, name, pay, ifunc.code_bytes, ifunc.deps, ifunc.digest
        )

    def forget_publisher(self, root: int) -> None:
        """Drop publish-dedup state for one root peer index.  A restarted
        peer re-mints pub_ids from zero; without this, its fresh publishes
        of already-seen code collide with the stale (digest, root, pub_id)
        keys recorded for its previous life and are silently dropped as
        duplicates — exactly-once would quietly become at-most-zero."""
        self._seen_pubs = {k for k in self._seen_pubs if k[1] != root}

    def publish_to(
        self,
        dst: str,
        name: str,
        payload: np.ndarray | bytes = b"",
        *,
        ttl: int = 1,
    ) -> None:
        """Publish directly to one named peer (no tree fan-out at this end;
        the receiver still re-publishes if ``ttl`` allows).  This is the
        re-parenting primitive: when a mid-tree PE dies, the root re-covers
        the orphaned subtree by publishing straight to its survivors."""
        ifunc = self._resolve_source(name)
        # a direct publish exists because the normal delivery is in doubt —
        # drop our cache belief so the code travels again (a dropped hop
        # upstream may have warmed this entry without the bytes ever landing)
        self.sender_cache.forget(dst, ifunc.digest.hex())
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        me = self.peer_index(self.name)
        self._pub_seq += 1
        hop = HopHeader(
            ttl=ttl, root=me, pub_id=self._pub_seq, path=(me,),
            k=self.propagation.k_code,
        )
        self._send_publish(
            dst, hop, ifunc.kind, name, pay, ifunc.code_bytes, ifunc.deps,
            ifunc.digest,
        )

    def _publish_to_children(
        self,
        hop: HopHeader,
        kind: FrameKind,
        name: str,
        inner: bytes,
        code: bytes,
        deps: tuple[str, ...],
        digest: bytes,
    ) -> list[str]:
        """Send one hop frame per tree child; a dead child loses only its
        own subtree's frame (counted), the rest of the fan-out proceeds."""
        me = self.peer_index(self.name)
        sent: list[str] = []
        for child in tree_children(hop.k, hop.root, me, len(self.peers)):
            dst = self.peers[child]
            try:
                self._send_publish(dst, hop, kind, name, inner, code, deps, digest)
                sent.append(dst)
            except EndpointDead:
                self.stats.publish_send_failures += 1
                # the PUT never landed: roll back the cache entry the send
                # just added, or a later re-publish would wrongly truncate
                self.sender_cache.forget(dst, digest.hex())
        return sent

    def _send_publish(
        self,
        dst: str,
        hop: HopHeader,
        kind: FrameKind,
        name: str,
        inner: bytes,
        code: bytes,
        deps: tuple[str, ...],
        digest: bytes,
    ) -> None:
        self._seq += 1
        frame = Frame(
            kind=kind,
            name=name,
            payload=pack_hop(hop) + inner,
            code=code,
            deps=deps,
            digest=digest,
            seq=self._seq,
            flags=FrameFlags.HOP,
        )
        self.stats.publishes += 1
        # publishes bypass the batching send queue even when batching is on:
        # hop frames never coalesce (per-edge path headers), and a dead
        # child must surface EndpointDead HERE — synchronously — so the
        # fan-out's per-child containment and sender-cache rollback apply
        # identically on both runtimes (a queued send would defer the error
        # to flush() and skip both).
        self._put_now(dst, frame)

    def submit(
        self,
        dst: str,
        name: str,
        body: np.ndarray,
        queue: "CompletionQueue",
        expected: int,
    ) -> "GatherFuture":
        """Submit a completion-tracked X-RDMA op and return its future.

        The completion-queue wire convention: the runtime prepends the
        routing header ``[requester, slot, epoch]`` to the caller's
        ``body``, so every shipped op under this protocol sees
        ``payload[0]`` = the requester's peer index, ``payload[1]`` = the
        slot its RETURNs must target, and ``payload[2]`` = the slot's
        generation tag (RETURN code drops stale generations, making slot
        recycling safe under at-least-once delivery).  ``expected`` is how
        many result units (e.g. resolved rows) must arrive — possibly via
        several out-of-order RETURNs from different PEs — before the
        future reads done.
        """
        slot, epoch = queue._alloc()
        hdr = np.array([self.peer_index(self.name), slot, epoch], np.int32)
        payload = np.concatenate([hdr, np.asarray(body, np.int32)])
        fut = GatherFuture(queue=queue, slot=slot, expected=int(expected))
        queue._inflight[slot] = fut
        try:
            self.send_ifunc(dst, name, payload)
        except Exception:
            fut.cancel()  # a failed send must not leak the slot
            raise
        return fut

    def peer_index(self, name: str) -> int:
        """This cluster's dense peer index for ``name`` (the index space
        X-RDMA action vectors use for ``dst``/``requester``)."""
        return self.peers.index(name)

    def _put_frame(self, dst: str, frame: Frame) -> int:
        """PUT a frame now, or queue it for the next :meth:`flush`.

        Returns wire bytes sent, or 0 when the frame was queued (the wire
        size of a queued frame is only known after coalescing).
        """
        if self.batching:
            self._sendq.setdefault(dst, []).append(frame)
            return 0
        return self._put_now(dst, frame)

    def _put_now(self, dst: str, frame: Frame) -> int:
        if frame.kind in (FrameKind.ACTIVE_MESSAGE, FrameKind.RNDV):
            cached = True  # AM / rendezvous descriptors never carry code
        else:
            cached = self.caching_enabled and self.sender_cache.check_and_add(
                dst, frame.digest.hex(), len(frame.code)
            )
        wire = frame.wire_bytes(cached=cached)
        self.stats.sends += 1
        if not cached and frame.code:
            self.stats.code_sends += 1
        self.fabric.put(
            self.name,
            dst,
            wire,
            n_payloads=frame.n_payloads,
            kinds=frame.kind_breakdown(cached),
            hop=bool(frame.flags & FrameFlags.HOP),
        )
        return len(wire)

    def flush(self) -> int:
        """Emit every queued frame and one-sided write burst.

        A burst of same-type frames to one peer travels as a single
        coalesced PUT (one ``alpha_us``, summed bytes); a burst of queued
        zero-copy slab writes to one peer travels as a single doorbell-
        batched WQE chain (one ``alpha_us``, one ``o_us`` per extra
        segment).  A failing destination (e.g. a killed endpoint) loses
        only its own traffic — every other destination's queue is still
        delivered, then the first error is re-raised.  Returns the number
        of wire operations issued.
        """
        queued, self._sendq = self._sendq, {}
        regionq, self._regionq = self._regionq, {}
        puts = 0
        errors: list[Exception] = []
        for dst, frames in queued.items():
            # group by ifunc type AND payload size (AM payloads are caller-
            # defined and xrdma plen varies, so same-name frames can be
            # ragged — those travel as separate coalesced PUTs), preserving
            # first-seen order.  PUBLISH hop frames never coalesce: each
            # carries its own per-edge path header.
            groups: dict[tuple[int, str, bytes, int, int], list[Frame]] = {}
            for f in frames:
                key = (
                    int(f.kind), f.name, f.digest, len(f.payload),
                    int(f.flags) & FrameFlags.HOP,
                )
                groups.setdefault(key, []).append(f)
            for key, members in groups.items():
                batch = [coalesce(members)] if not key[4] else members
                for frame in batch:
                    try:
                        self._put_now(dst, frame)
                        puts += 1
                    except Exception as e:  # noqa: BLE001 - deliver the rest first
                        errors.append(e)
        for dst, writes in regionq.items():
            try:
                self.fabric.put_region_multi(self.name, dst, writes)
                puts += 1
            except Exception as e:  # noqa: BLE001 - deliver the rest first
                errors.append(e)
        if puts:
            self.stats.flushes += 1
        if errors:
            raise errors[0]
        return puts

    # --- target side --------------------------------------------------------
    def poll(self, max_msgs: int | None = None) -> int:
        """Drain the endpoint buffer, installing and invoking arrivals.

        This is the paper's 'UCX ifunc polling function' — ideally called
        from a daemon thread; tests and the single-core benchmarks call it
        from a round-robin scheduler (core.cluster).

        With :attr:`batching` on, the drained frames are grouped by code
        digest, each group's payloads are decoded into one ``(B, ...)``
        block and retired by a single batched XLA dispatch, and everything
        the dispatches emitted is flushed as coalesced per-destination PUTs.
        """
        if not self.batching:
            n = 0
            for buf in self.endpoint.drain():
                self._handle(bytes(buf))
                n += 1
                self.stats.msgs += 1
                if max_msgs is not None and n >= max_msgs:
                    break
            return n
        bufs: list[bytes] = []
        for buf in self.endpoint.drain():
            bufs.append(bytes(buf))
            self.stats.msgs += 1
            if max_msgs is not None and len(bufs) >= max_msgs:
                break
        if bufs:
            try:
                self._handle_batch(bufs)
            finally:
                self.flush()  # emitted actions travel even if a frame was bad
        return len(bufs)

    def _handle_am(self, frame: Frame) -> None:
        handler = self.am_table.get(frame.name)
        if handler is None:
            raise ProtocolError(f"{self.name}: no AM handler {frame.name!r}")
        for pay in split_payloads(frame):
            self.stats.am_handled += 1
            handler(self, pay)

    # --- recursive propagation: target side ---------------------------------
    def _handle_publish(self, buf: bytes, hdr) -> None:
        """One PUBLISH hop: validate -> install -> invoke -> re-publish.

        The validation ladder runs *before* anything is installed or
        invoked, in blast-radius order (Kourtis et al.: injected code must
        be validated at every hop, not only at the origin):

        1. poisoned code — the code section's sha256 must equal the header
           digest; a mismatch is refused loudly and, crucially, is NOT
           re-published, so a poisoned frame cannot ride the tree.
        2. duplicate — (code digest, root, pub_id) already handled here:
           dropped silently (the fabric is at-least-once; re-delivery is
           normal, and the drop is what makes a forwarding loop starve).
        3. ttl expired — a frame arriving with no hop budget left was
           forwarded by a peer that should have stopped: refused loudly.
        4. cycle — this PE's own index on the visited path: refused loudly
           (the path digest was already verified by the hop parser).

        An accepted publish installs the code, invokes the payload (if the
        publish carries one — a bare publish is pure code distribution),
        and re-publishes code + payload to its tree children with one hop
        spent and itself appended to the path.  Warm children receive
        digest-only frames: the SenderCache truncation applies to hop
        frames exactly as to point-to-point sends.
        """
        has_code = len(buf) >= hdr.full_total and hdr.code_len > 0
        frame = unpack(buf, has_code=has_code)
        if frame.flags & FrameFlags.BATCH:
            raise ProtocolError(f"{self.name}: publish frames never coalesce")
        hop, inner = split_hop(frame.payload)  # CorruptFrame on tampering
        me = self.peer_index(self.name)
        if has_code and hashlib.sha256(frame.code).digest() != frame.digest:
            self.stats.publish_refused_digest += 1
            raise ProtocolError(
                f"{self.name}: publish of {hdr.name!r} carries code that does "
                f"not match its digest (poisoned code refused, not re-published)"
            )
        key = (hdr.digest, hop.root, hop.pub_id)
        if key in self._seen_pubs:
            self.stats.publish_dupes += 1
            return
        if hop.ttl <= 0:
            self.stats.publish_refused_ttl += 1
            raise ProtocolError(
                f"{self.name}: publish of {hdr.name!r} arrived with expired "
                f"ttl (path {hop.path})"
            )
        if me in hop.path:
            self.stats.publish_refused_cycle += 1
            raise ProtocolError(
                f"{self.name}: publish of {hdr.name!r} would cycle — own "
                f"index {me} already on path {hop.path}"
            )
        if has_code:
            exe = self._install(frame)
        else:
            exe = self.target_cache.lookup(hdr.name)
            if exe is None or exe.digest != hdr.digest.hex():
                hit = self.target_cache.lookup_digest(hdr.digest.hex())
                if hit is None:
                    raise ProtocolError(
                        f"{self.name}: digest-only publish for unknown code "
                        f"{hdr.name!r} (stale sender cache — was this PE "
                        f"restarted?)"
                    )
                exe = CachedExecutable(
                    name=hdr.name,
                    digest=hit.digest,
                    fn=hit.fn,
                    in_avals=hit.in_avals,
                    deps=hit.deps,
                    kind=int(hdr.kind),
                    extras=dict(hit.extras),
                )
                self.target_cache.install(exe, jit_ms=0.0)
                self.stats.ifunc_installs += 1
        self._seen_pubs.add(key)
        self.stats.publish_handled += 1
        if inner:
            self._invoke(exe, inner)
        children = tree_children(hop.k, hop.root, me, len(self.peers))
        if not children:
            return
        if hop.ttl < 2:
            self.stats.publish_stopped_ttl += 1
            return
        code = frame.code if has_code else exe.extras.get("code", b"")
        self._publish_to_children(
            hop.child_hop(me),
            FrameKind(exe.kind),
            exe.name,
            inner,
            code,
            exe.deps,
            bytes.fromhex(exe.digest),
        )

    def _rndv_pull(self, name: str, desc: bytes) -> tuple[CachedExecutable, bytes]:
        """Resolve a rendezvous descriptor: GET the staged payload from the
        source's staging region.  The executable must already be cached —
        descriptors cannot carry code (the sender only selects rendezvous
        for cache-warm peers), so a miss here means a stale sender cache."""
        src_idx, token, nbytes = unpack_rndv(desc)  # CorruptFrame if malformed
        exe = self.target_cache.lookup(name)
        if exe is None:
            raise ProtocolError(
                f"{self.name}: rendezvous descriptor for unregistered ifunc "
                f"{name!r} (stale sender cache — was this PE restarted?)"
            )
        if not 0 <= src_idx < len(self.peers):
            raise ProtocolError(f"{self.name}: rendezvous src index {src_idx} out of range")
        src = self.peers[src_idx]
        try:
            data = self.fabric.get(self.name, src, rndv_region(src, token), 0, nbytes)
        except KeyError:
            # staging ring evicted the region, or the source restarted with
            # fresh (empty) registered memory — loud but contained, like the
            # framed path's stale-sender-cache refusal
            raise ProtocolError(
                f"{self.name}: rendezvous staging region for token {token} "
                f"gone at {src!r} (evicted or source restarted)"
            ) from None
        return exe, data

    def _resolve_exe(self, buf: bytes, hdr) -> tuple[CachedExecutable, Frame]:
        """Find (or install) the executable a frame refers to; returns it
        with the frame unpacked exactly once (code-carrying frames are
        multi-KB, a second parse is a second copy).

        The name registry decides whether a truncated frame is acceptable;
        the digest decides whether the name's code is *current* — a frame
        carrying new code under a known name (republished ifunc) installs
        and supersedes, it never silently runs the stale executable.
        """
        has_code = len(buf) >= hdr.full_total and hdr.code_len > 0
        frame = unpack(buf, has_code=has_code)
        if not self.target_cache.has_name(hdr.name):
            if not has_code:
                raise ProtocolError(
                    f"{self.name}: truncated frame for unregistered ifunc "
                    f"{hdr.name!r} (stale sender cache — was this PE restarted?)"
                )
            return self._install(frame), frame
        exe = self.target_cache.lookup(hdr.name)
        assert exe is not None
        if exe.digest != hdr.digest.hex():
            if has_code:
                return self._install(frame), frame
            hit = self.target_cache.lookup_digest(hdr.digest.hex())
            if hit is None:
                raise ProtocolError(
                    f"{self.name}: truncated frame for {hdr.name!r} with "
                    f"unknown code digest (stale sender cache)"
                )
            exe = hit
        return exe, frame

    def _handle(self, buf: bytes) -> None:
        hdr = peek_header(buf)
        if hdr is None:
            raise ProtocolError("short frame")
        if hdr.flags & FrameFlags.HOP:
            self._handle_publish(buf, hdr)
            return
        if hdr.kind == FrameKind.ACTIVE_MESSAGE:
            self._handle_am(unpack(buf, has_code=False))
            return
        if hdr.kind == FrameKind.RNDV:
            frame = unpack(buf, has_code=False)
            for desc in split_payloads(frame):
                exe, data = self._rndv_pull(frame.name, desc)
                self._invoke(exe, data)
            return
        # ifunc path: does this wire carry code? (sender truncates iff it
        # believes we have it; len tells the truth, the registry must agree)
        exe, frame = self._resolve_exe(buf, hdr)
        for pay in split_payloads(frame):
            self._invoke(exe, pay)

    def _handle_batch(self, bufs: list[bytes]) -> None:
        """Group drained frames by code digest and invoke each group once.

        A frame that fails to resolve (stale sender cache after a restart)
        or a group that fails to invoke (corrupt payload block) must not
        take the rest of the drained batch down with it: every healthy
        frame/group is still processed, then the first error is re-raised —
        the same blast radius as the per-message path.
        """
        groups: dict[bytes, tuple[CachedExecutable, list[bytes]]] = {}
        errors: list[Exception] = []
        for buf in bufs:
            try:
                hdr = peek_header(buf)
                if hdr is None:
                    raise ProtocolError("short frame")
                if hdr.flags & FrameFlags.HOP:
                    # publishes are install-dominated and rare (one per PE
                    # per code distribution): handled inline, re-publishes
                    # ride the post-poll flush as everything else does
                    self._handle_publish(buf, hdr)
                    continue
                if hdr.kind == FrameKind.ACTIVE_MESSAGE:
                    self._handle_am(unpack(buf, has_code=False))
                    continue
                if hdr.kind == FrameKind.RNDV:
                    # pull each staged payload, then fold it into the same
                    # digest group as any framed payloads of the same ifunc:
                    # rendezvous and eager arrivals retire in ONE dispatch
                    frame = unpack(buf, has_code=False)
                    for desc in split_payloads(frame):
                        exe, data = self._rndv_pull(frame.name, desc)
                        entry = groups.setdefault(bytes.fromhex(exe.digest), (exe, []))
                        entry[1].append(data)
                    continue
                exe, frame = self._resolve_exe(buf, hdr)
                entry = groups.setdefault(hdr.digest, (exe, []))
                entry[1].extend(split_payloads(frame))
            except (ProtocolError, ValueError, ISAMismatch, EndpointDead) as e:
                errors.append(e)
        for exe, pays in groups.values():
            try:
                self._invoke_batch(exe, pays)
            except Exception as e:  # noqa: BLE001 - process remaining groups
                errors.append(e)
        if errors:
            raise errors[0]

    def _install(self, frame: Frame) -> CachedExecutable:
        """Extract slice -> (ORC-)JIT -> digest cache (Sec. III-C/D).

        A digest hit skips compilation entirely (ORC-JIT's internal symbol
        cache, which the paper observed makes re-JIT of already-seen code
        free) — only the name registration is new."""
        hit = self.target_cache.lookup_digest(frame.digest.hex())
        if hit is not None:
            exe = CachedExecutable(
                name=frame.name,
                digest=hit.digest,
                fn=hit.fn,
                in_avals=hit.in_avals,
                deps=frame.deps or hit.deps,
                kind=int(frame.kind),
                extras=dict(hit.extras),
            )
            self.target_cache.install(exe, jit_ms=0.0)
            self.stats.ifunc_installs += 1
            return exe
        from .bitcode import BitcodeSlice  # noqa: F401  (documented type)

        fat = FatBitcode.from_bytes(frame.code)
        if frame.kind == FrameKind.BINARY:
            # binary code is ISA/uarch-specific: exact triple or bust
            if self.triple not in fat.slices:
                raise ISAMismatch(
                    f"binary ifunc {frame.name!r} built for {fat.triples()} "
                    f"cannot run on {self.triple!r} (Sec. III-B problem; "
                    f"ship bitcode instead)"
                )
            blob = fat.slices[self.triple]
        else:
            blob = fat.extract(self.triple).blob
        t0 = time.perf_counter()
        exported = jax.export.deserialize(blob)
        compiled = jax.jit(exported.call).lower(*exported.in_avals).compile()
        jit_ms = (time.perf_counter() - t0) * 1e3
        abi = "pure"
        for d in frame.deps:
            if d.startswith("abi:"):
                abi = d.split(":", 1)[1]
        exe = CachedExecutable(
            name=frame.name,
            digest=frame.digest.hex(),
            fn=compiled,
            in_avals=tuple(exported.in_avals),
            deps=frame.deps,
            kind=int(frame.kind),
            extras={"code": frame.code, "abi": abi, "exported": exported},
        )
        self.target_cache.install(exe, jit_ms=jit_ms)
        self.stats.ifunc_installs += 1
        self.stats.jit_ms_total += jit_ms
        return exe

    # --- invoke -------------------------------------------------------------
    def _decode_payload(self, exe: CachedExecutable, payload: bytes) -> np.ndarray:
        aval = exe.in_avals[0]
        arr = np.frombuffer(payload, dtype=aval.dtype)
        return arr.reshape(aval.shape)

    def _dep_args(self, exe: CachedExecutable) -> list[Any]:
        args: list[Any] = []
        for d in exe.deps:
            tag, _, val = d.partition(":")
            if tag == "region":
                args.append(self._region_device(val))
            elif tag == "cap":
                args.append(self.caps[val])
        return args

    @staticmethod
    def _region_arg_pos(exe: CachedExecutable) -> int:
        """Position of the (single) region among the linked dep arguments."""
        pos = 0
        for d in exe.deps:
            tag, _, _ = d.partition(":")
            if tag == "region":
                return pos
            if tag == "cap":
                pos += 1
        raise AssertionError("update ABI requires a region dep")

    def _dep_named(self, exe: CachedExecutable, tag: str) -> str | None:
        for d in exe.deps:
            t, _, val = d.partition(":")
            if t == tag:
                return val
        return None

    def _invoke(self, exe: CachedExecutable, payload: bytes) -> None:
        self.stats.invokes += 1
        self.stats.invoked_payloads += 1
        pay = self._decode_payload(exe, payload)
        args = self._dep_args(exe)
        out = exe.fn(pay, *args)
        abi = exe.extras.get("abi", "pure")
        if abi == "update":
            region = self._dep_named(exe, "region")
            assert region is not None, "update ABI requires a region dep"
            self._write_region(region, np.asarray(out))
        elif abi == "propagate":
            region = self._dep_named(exe, "region")
            assert region is not None, "propagate ABI requires a region dep"
            new_region, actions = out
            self._write_region(region, np.asarray(new_region))
            self._apply_actions(exe, np.asarray(actions))
        elif abi == "xrdma":
            self._apply_actions(exe, np.asarray(out))
        else:  # pure
            self.completed.append(np.asarray(out))

    # --- batched invoke -----------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        """Power-of-two padding bucket: bounds batched recompiles to log2."""
        return 1 << max(0, n - 1).bit_length()

    def _decode_payload_block(
        self, exe: CachedExecutable, pays: list[bytes], bucket: int
    ) -> np.ndarray:
        """Decode N same-type payloads into a ``(bucket, ...)`` block.

        Padding rows repeat the last real payload: a real payload is known
        to terminate (e.g. a Chaser's ``while_loop`` bound), so edge-repeat
        padding can never hang where zero-padding might; padded outputs are
        simply discarded.
        """
        aval = exe.in_avals[0]
        arr = np.frombuffer(b"".join(pays), dtype=aval.dtype)
        arr = arr.reshape((len(pays), *aval.shape))
        if bucket > len(pays):
            arr = np.concatenate([arr, np.repeat(arr[-1:], bucket - len(pays), axis=0)])
        return arr

    def _batched_executable(self, exe: CachedExecutable, bucket: int):
        """The vmapped rendering of an installed ifunc, cached per
        (digest, bucket) in the target code cache.

        ``jax.vmap`` over a deserialized export blob needs a batching rule
        for ``call_exported``; where the installed JAX version lacks one,
        the fallback is ``lax.map`` — sequential semantics inside ONE fused
        XLA dispatch, which is the quantity being amortized.  update-ABI
        code folds payloads into the region carry with a masked ``lax.scan``
        (exact sequential semantics, one dispatch, one region write).
        """
        hit = self.target_cache.lookup_batched(exe.digest, bucket)
        if hit is not None:
            return hit
        exported = exe.extras["exported"]
        call = exported.call
        abi = exe.extras.get("abi", "pure")
        pay_aval = exe.in_avals[0]
        block_aval = jax.ShapeDtypeStruct((bucket, *pay_aval.shape), pay_aval.dtype)
        dep_avals = tuple(exe.in_avals[1:])
        t0 = time.perf_counter()
        if abi in ("update", "propagate"):
            # entry(payload, ..region.., ...) -> new_region (update) or
            # (new_region, actions) (propagate), folded as a scan carry;
            # padded rows are masked out so the fold is exact — a masked
            # propagate row contributes neither to the region nor an action
            # (its row is overwritten with NOPs).
            valid_aval = jax.ShapeDtypeStruct((bucket,), jnp.bool_)
            rpos = self._region_arg_pos(exe)

            def folded(pays, valid, region, *extra):
                def step(r, pv):
                    p, v = pv
                    dep_args = list(extra)
                    dep_args.insert(rpos, r)
                    if abi == "propagate":
                        nr, acts = call(p, *dep_args)
                        nops = jnp.zeros_like(acts).at[..., 0].set(A_NOP)
                        return jnp.where(v, nr, r), jnp.where(v, acts, nops)
                    return jnp.where(v, call(p, *dep_args), r), None

                carry, ys = lax.scan(step, region, (pays, valid))
                return (carry, ys) if abi == "propagate" else carry

            extra_avals = [a for i, a in enumerate(dep_avals) if i != rpos]
            compiled = (
                jax.jit(folded)
                .lower(block_aval, valid_aval, dep_avals[rpos], *extra_avals)
                .compile()
            )
        else:
            def vmapped(pays, *deps):
                return jax.vmap(call, in_axes=(0, *([None] * len(dep_avals))))(
                    pays, *deps
                )

            def mapped(pays, *deps):
                return lax.map(lambda p: call(p, *deps), pays)

            compiled = None
            for impl in (vmapped, mapped):
                try:
                    compiled = jax.jit(impl).lower(block_aval, *dep_avals).compile()
                    break
                except NotImplementedError:
                    continue
            assert compiled is not None
        self.stats.jit_ms_total += (time.perf_counter() - t0) * 1e3
        self.target_cache.install_batched(exe.digest, bucket, compiled)
        return compiled

    def _invoke_batch(self, exe: CachedExecutable, pays: list[bytes]) -> None:
        """Retire N same-ifunc payloads in one XLA dispatch."""
        if len(pays) == 1:  # the per-message executable is already compiled
            self._invoke(exe, pays[0])
            return
        n = len(pays)
        bucket = self._bucket(n)
        block = self._decode_payload_block(exe, pays, bucket)
        fn = self._batched_executable(exe, bucket)
        args = self._dep_args(exe)
        abi = exe.extras.get("abi", "pure")
        self.stats.invokes += 1
        self.stats.batched_invokes += 1
        self.stats.invoked_payloads += n
        if abi in ("update", "propagate"):
            region = self._dep_named(exe, "region")
            assert region is not None, f"{abi} ABI requires a region dep"
            valid = np.arange(bucket) < n
            rpos = self._region_arg_pos(exe)
            extra = [a for i, a in enumerate(args) if i != rpos]
            out = fn(block, valid, args[rpos], *extra)
            if abi == "propagate":
                out, acts = out
                self._write_region(region, np.asarray(out))
                # padded rows were masked to NOPs inside the scan; applying
                # the real rows in payload order preserves the sequential
                # semantics (the row that completes a fold emits the action)
                for per_payload in np.asarray(acts)[:n]:
                    self._apply_actions(exe, per_payload)
            else:
                self._write_region(region, np.asarray(out))
        elif abi == "xrdma":
            actions = np.asarray(fn(block, *args))[:n]
            for per_payload in actions:
                self._apply_actions(exe, per_payload)
        else:  # pure
            outs = np.asarray(fn(block, *args))[:n]
            self.completed.extend(outs)

    def _apply_actions(self, exe: CachedExecutable, out: np.ndarray) -> None:
        """Apply what an xrdma entry returned: one action vector, or an
        (R, W) matrix of action rows applied in order (see module docstring)."""
        if out.ndim == 2:
            for row in out:
                self._apply_action(exe, row)
        else:
            self._apply_action(exe, out)

    def _apply_action(self, exe: CachedExecutable, action: np.ndarray) -> None:
        """The fixed X-RDMA action protocol (see module docstring)."""
        code = int(action[0])
        dst_idx = int(action[1])
        plen = int(action[2])
        pay = np.ascontiguousarray(action[3 : 3 + plen])
        if code == A_NOP:
            return
        if code == A_DONE:
            self.completed.append(pay)
            return
        dst = self.peers[dst_idx]
        if code == A_FORWARD:
            self.stats.forwards += 1
            self._seq += 1
            frame = Frame(
                kind=FrameKind(exe.kind),
                name=exe.name,
                payload=pay.tobytes(),
                code=exe.extras["code"],
                deps=exe.deps,
                digest=bytes.fromhex(exe.digest),
                seq=self._seq,
            )
            self._put_frame(dst, frame)
        elif code == A_RETURN:
            self.stats.returns += 1
            target = self._dep_named(exe, "returns")
            assert target is not None, "RETURN requires a returns: dep"
            self._return_payload(dst, target, pay)
        elif code == A_SPAWN:
            self.stats.spawns += 1
            target = self._dep_named(exe, "spawn")
            assert target is not None, "SPAWN requires a spawn: dep"
            self.send_ifunc(dst, target, pay)
        elif code == A_PUBLISH:
            # shipped code re-publishing *itself*: p0 is the hop budget it
            # grants, the rest travels as the published payload — the
            # paper's "recursively propagate itself" emitted by the code,
            # not the runtime
            me = self.peer_index(self.name)
            self._pub_seq += 1
            hop = HopHeader(
                ttl=int(pay[0]),
                root=me,
                pub_id=self._pub_seq,
                path=(me,),
                k=self.propagation.k_code,
            )
            try:
                self._send_publish(
                    dst,
                    hop,
                    FrameKind(exe.kind),
                    exe.name,
                    np.ascontiguousarray(pay[1:]).tobytes(),
                    exe.extras.get("code", b""),
                    exe.deps,
                    bytes.fromhex(exe.digest),
                )
            except EndpointDead:
                self.stats.publish_send_failures += 1
        else:
            raise ProtocolError(f"bad action code {code}")

    # --- data plane: protocol-selected RETURNs ------------------------------
    def _return_payload(self, dst: str, target: str, pay: np.ndarray) -> None:
        """Ship one RETURN payload under the data plane's protocol selection.

        ``framed`` re-injects the RETURN ifunc (PR 1 path, coalescable);
        ``zerocopy`` writes the payload one-sidedly into the requester's
        registered slab per the ifunc's :class:`SlabLayout` and bumps the
        doorbell — no frame, no requester-side dispatch; ``rendezvous``
        stages the payload locally and frames only a 16-byte descriptor
        the requester GETs against.
        """
        ifn = self._resolve_source(target)
        proto = self.dataplane.select(
            int(pay.nbytes),
            slab=ifn.slab is not None,
            code_cached=self.caching_enabled
            and self.sender_cache.has(dst, ifn.digest.hex()),
        )
        if proto == "zerocopy":
            self.stats.zerocopy_returns += 1
            writes = ifn.slab.plan(np.ascontiguousarray(pay, np.int32))
            if self.batching:
                self._regionq.setdefault(dst, []).extend(writes)
            else:
                self.fabric.put_region_multi(self.name, dst, writes)
        elif proto == "rendezvous":
            self.stats.rndv_returns += 1
            self._rndv_send(dst, ifn, pay)
        else:
            self.send_ifunc(dst, target, pay)

    def _rndv_send(self, dst: str, ifn: IFunc, pay: np.ndarray) -> None:
        """Rendezvous RETURN: stage the payload in a source-registered
        region and frame only the 16-byte descriptor; the requester pulls
        the data with a one-sided GET (cost ``2*alpha + n/beta``, correct
        when the payload dwarfs ``2*alpha``)."""
        token = self._rndv_seq
        self._rndv_seq += 1
        staging = rndv_region(self.name, token)
        # explicit copy: `pay` may be a view into a whole batched action
        # matrix, and registering the view would pin that matrix in the
        # staging ring long after the dispatch that produced it
        data = np.array(pay, np.int32)
        self.endpoint.register_region(staging, data)
        self._rndv_tokens.append(staging)
        while len(self._rndv_tokens) > RNDV_STAGING_DEPTH:
            self.endpoint.unregister_region(self._rndv_tokens.popleft())
        desc = pack_rndv(self.peer_index(self.name), token, data.nbytes)
        self._seq += 1
        self._put_frame(
            dst, Frame(kind=FrameKind.RNDV, name=ifn.name, payload=desc, seq=self._seq)
        )


# ----------------------------------------------------- completion queue
class CompletionQueue:
    """Client-side completion queue for in-flight X-RDMA submissions.

    The paper's ifuncs complete by writing into requester memory the
    requester polls (ReturnResult + a counter).  This layer generalizes
    that to *many overlapped operations*: a results region laid out as
    ``(max_slots, 2 + width)`` int32 rows — ``row[0]`` is the slot's
    arrived-position bitmask (popcount = distinct results arrived, so a
    re-delivered partial RETURN ORs in bits it already set and can never
    complete a slot early), ``row[1]`` its generation tag (epoch),
    ``row[2:]`` its data block — plus a free-list of slots and a future
    per in-flight submission.  RETURN ifuncs
    (e.g. :func:`repro.core.xrdma.make_gather_return`) scatter into a
    slot's block and bump its counter; because each RETURN names its slot,
    completions may arrive *out of order* and interleaved across many
    in-flight gathers, and retire through the batched update-ABI fold in
    one XLA dispatch per poll.  Each allocation bumps the slot's epoch and
    stamps it into every frame of that submission, so a late or
    re-delivered RETURN for a *retired* gather mismatches the recycled
    slot's generation and is dropped by the RETURN code — at-least-once
    delivery cannot corrupt a successor request.  Completion is
    poll-driven: nothing blocks, :meth:`GatherFuture.done` just reads the
    counter the next poll wrote.

    ``shape`` is the logical shape of one slot's data block (e.g.
    ``(n_keys, dim)`` for a gather); ``dtype`` its logical element type —
    the wire/region representation is always int32 (bit-cast, never
    converted, so float rows survive bit-identically).

    The results region doubles as the zero-copy data plane's registered
    slab: under ``DataPlaneConfig.zero_copy`` the remote PE WRITEs partial
    rows straight into the slot's data words and the fabric ORs the
    arrived-position bits into ``row[0]`` as the doorbell, guarded by the
    generation word ``row[1]`` — so ``done()``/``result()`` poll the same
    memory whether results arrived framed, one-sided, or mixed.
    """

    def __init__(
        self,
        pe: PE,
        shape: tuple[int, ...],
        dtype=np.int32,
        max_slots: int = 64,
        region: str = "cq_results",
    ) -> None:
        self.pe = pe
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        assert self.dtype.itemsize == 4, "slot blocks are int32-word addressed"
        self.width = int(np.prod(self.shape))
        self.max_slots = max_slots
        self.region = region
        pe.register_region(region, np.zeros((max_slots, 2 + self.width), np.int32))
        self._free: deque[int] = deque(range(max_slots))
        self._inflight: dict[int, "GatherFuture"] = {}

    # -- slot lifecycle ----------------------------------------------------
    def _alloc(self) -> tuple[int, int]:
        """Take a free slot and advance its generation; -> (slot, epoch)."""
        if not self._free:
            raise RuntimeError(
                f"completion queue full ({self.max_slots} slots in flight); "
                "poll and retire futures before submitting more"
            )
        slot = self._free.popleft()
        arr = self.pe.region(self.region)
        epoch = int(arr[slot, 1]) + 1
        arr[slot, 0] = 0
        arr[slot, 1] = epoch
        arr[slot, 2:] = 0
        # re-register so the device-resident copy the RETURN fold reads is
        # refreshed with the new generation tag
        self.pe.register_region(self.region, arr)
        return slot, epoch

    def _release(self, slot: int) -> None:
        # count/data cleared on next _alloc; the epoch stays, so RETURNs
        # still in flight for the retired generation mismatch and drop
        self._inflight.pop(slot, None)
        self._free.append(slot)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def _count(self, slot: int) -> int:
        """Distinct results arrived: popcount of the position bitmask."""
        return bin(int(self.pe.region(self.region)[slot, 0]) & 0xFFFFFFFF).count("1")

    def _data(self, slot: int) -> np.ndarray:
        raw = self.pe.region(self.region)[slot, 2:]
        return raw.view(self.dtype).reshape(self.shape)

    def completed(self) -> list["GatherFuture"]:
        """Every in-flight future whose results have fully arrived."""
        return [f for f in list(self._inflight.values()) if f.done()]


@dataclass
class GatherFuture:
    """Poll-driven handle for one completion-queue submission.

    ``done()`` becomes true once ``expected`` result units have been
    RETURNed into the slot (out-of-order, possibly from several PEs);
    ``result()`` copies the slot's data block out and recycles the slot.
    ``cancel()`` abandons an in-flight submission (failed send, lost
    frame) and recycles the slot — the epoch guard makes that safe even
    if the abandoned gather's RETURNs later arrive.  ``meta`` is caller
    scratch (e.g. the original un-padded key batch).
    """

    queue: CompletionQueue
    slot: int
    expected: int
    meta: Any = None
    _released: bool = False

    def done(self) -> bool:
        return not self._released and self.queue._count(self.slot) >= self.expected

    def result(self, release: bool = True) -> np.ndarray:
        if self._released:
            raise RuntimeError("future already consumed")
        if not self.done():
            raise RuntimeError(
                f"slot {self.slot} incomplete: "
                f"{self.queue._count(self.slot)}/{self.expected} results arrived"
            )
        out = self.queue._data(self.slot).copy()
        if release:
            self._released = True
            self.queue._release(self.slot)
        return out

    def cancel(self) -> None:
        """Abandon this submission and recycle its slot (idempotent)."""
        if not self._released:
            self._released = True
            self.queue._release(self.slot)
