"""ifuncs: injected functions — code that travels with the message.

Source side, an :class:`IFunc` couples an entry function (a pure JAX
function) with its fat-bitcode archive (``jax.export`` blobs for every
toolchain target, Sec. III-C) and its dependency list (Sec. III-C ``.deps``).
Target side, a :class:`PE` (processing element) polls its endpoint, installs
arriving code (extract slice -> deserialize -> target-side JIT -> digest
cache) and invokes it.

ABI — how the runtime and injected code meet
--------------------------------------------
The paper's ifunc entry is ``main(payload, payload_size, target_ptr)`` and
may call UCX itself (via remote dynamic linking) to recursively re-inject
itself.  An XLA executable cannot call back into the transport mid-flight,
so the TPU-idiomatic rendering keeps the *decision logic in the shipped
code* and leaves only a fixed, function-agnostic action protocol in the
runtime (the moral equivalent of the UCX API the paper's ifuncs link
against):

* ``update`` ABI — ``entry(payload, region) -> new_region``.  The runtime
  stores the result back into the named memory region (TSI's counter).
* ``xrdma`` ABI — ``entry(payload, *linked_deps) -> i64[ACTION_WIDTH]``
  action vector::

      [action, dst, plen, p0 .. p7]

  ``action``: 0 DONE | 1 FORWARD (re-inject *this same ifunc*, code and
  all, to peer ``dst`` with payload ``p[:plen]``) | 2 RETURN (send the
  ifunc named by the ``returns:`` dep to ``dst``) | 3 SPAWN (send the
  ifunc named by the ``spawn:`` dep — "generate new code").

  Local recursion — the paper's "ifunc calls itself recursively" when the
  next pointer is local — happens *inside* the shipped code as a
  ``lax.while_loop``: the blob chases until the frontier leaves its shard,
  then emits FORWARD.  One network action per locality break, exactly the
  paper's DAPC behaviour.

Dependency tags (the wire ``DEPS`` list, Sec. III-C):

* ``abi:<update|xrdma|pure>`` — invoke convention.
* ``region:<name>`` — link the PE's registered memory region as an argument.
* ``cap:<name>``    — link a host capability (small constant array, e.g.
  shard metadata) as an argument.
* ``returns:<ifunc>`` / ``spawn:<ifunc>`` — ifunc types this code may emit;
  resolved through the PE's source registry / toolchain at action time.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import numpy as np

from .bitcode import DEFAULT_TOOLCHAIN_TARGETS, FatBitcode, platform_of
from .cache import CachedExecutable, SenderCache, TargetCodeCache
from .frame import Frame, FrameKind, peek_header, unpack
from .transport import Fabric

ACTION_WIDTH = 11  # [action, dst, plen, p0..p7]
A_DONE, A_FORWARD, A_RETURN, A_SPAWN = 0, 1, 2, 3


class ProtocolError(RuntimeError):
    pass


class ISAMismatch(RuntimeError):
    """Binary ifunc landed on a PE whose triple it was not compiled for."""


# ----------------------------------------------------------------- source
@dataclass
class IFunc:
    """Source-side handle: name + fat-bitcode + deps (paper Fig. 1 register)."""

    name: str
    fat: FatBitcode
    deps: tuple[str, ...]
    abi: str
    payload_aval: jax.ShapeDtypeStruct
    kind: FrameKind = FrameKind.BITCODE

    @property
    def code_bytes(self) -> bytes:
        return self.fat.to_bytes()

    @property
    def digest(self) -> bytes:
        import hashlib

        return hashlib.sha256(self.code_bytes).digest()

    @classmethod
    def build(
        cls,
        name: str,
        fn: Callable[..., Any],
        payload_aval: jax.ShapeDtypeStruct,
        dep_avals: Sequence[jax.ShapeDtypeStruct] = (),
        deps: Sequence[str] = (),
        abi: str = "pure",
        targets: Sequence[str] = DEFAULT_TOOLCHAIN_TARGETS,
        kind: FrameKind = FrameKind.BITCODE,
    ) -> "IFunc":
        """Run the Three-Chains toolchain: cross-compile ``fn`` for every
        target triple into a fat-bitcode archive.

        ``kind=BINARY`` models Sec. III-B: the archive holds exactly one
        slice (the source machine's own triple) and the target will refuse
        a triple mismatch instead of re-lowering.
        """
        if kind == FrameKind.BINARY and len(targets) != 1:
            raise ValueError("binary ifuncs are single-triple by definition")
        fat = FatBitcode.build(fn, (payload_aval, *dep_avals), targets=targets)
        wire_deps = (f"abi:{abi}", *deps)
        return cls(
            name=name,
            fat=fat,
            deps=wire_deps,
            abi=abi,
            payload_aval=payload_aval,
            kind=kind,
        )

    def make_frame(self, payload: bytes, seq: int = 0) -> Frame:
        return Frame(
            kind=self.kind,
            name=self.name,
            payload=payload,
            code=self.code_bytes,
            deps=self.deps,
            digest=self.digest,
            seq=seq,
        )


class Toolchain:
    """The shared filesystem of toolchain artifacts (paper Fig. 1: generated
    files 'placed in a directory that can be located by Three-Chains').

    Any PE may *register as a sender* from here — that is how a server that
    received a Chaser can emit a ReturnResult it never received over the
    wire, just as the paper's SPMD app binaries can register any ifunc
    library present on their local disk.  What is NOT pre-deployed is the
    target-side executable: code still travels in frames and installs via
    the cache protocol.
    """

    def __init__(self) -> None:
        self._artifacts: dict[str, IFunc] = {}

    def publish(self, ifunc: IFunc) -> IFunc:
        self._artifacts[ifunc.name] = ifunc
        return ifunc

    def lookup(self, name: str) -> IFunc:
        return self._artifacts[name]

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._artifacts))


# ----------------------------------------------------------------- target
@dataclass
class PEStats:
    msgs: int = 0
    ifunc_installs: int = 0
    invokes: int = 0
    forwards: int = 0
    returns: int = 0
    spawns: int = 0
    am_handled: int = 0
    jit_ms_total: float = 0.0

    def as_dict(self) -> dict[str, float]:
        d = self.__dict__.copy()
        d["jit_ms_total"] = round(self.jit_ms_total, 3)
        return d


class PE:
    """A processing element: endpoint + ifunc runtime + caches + local state.

    ``triple`` models the ISA/uarch (hosts are ``cpu-host`` Xeons, DPUs are
    ``cpu-bf2`` BlueField Arm cores, A64FX nodes ``cpu-a64fx``); on this
    container all execute on the CPU backend, but triple *mismatch logic* is
    real: binary ifuncs require an exact triple, fat-bitcode falls back by
    platform and re-optimizes locally (Sec. III-C).
    """

    def __init__(
        self,
        name: str,
        fabric: Fabric,
        triple: str = "cpu-host",
        toolchain: Toolchain | None = None,
        peers: Sequence[str] = (),
    ) -> None:
        platform_of(triple)  # validate
        self.name = name
        self.triple = triple
        self.fabric = fabric
        self.endpoint = fabric.connect(name)
        self.toolchain = toolchain
        self.peers: list[str] = list(peers)
        self.target_cache = TargetCodeCache()
        self.sender_cache = SenderCache()
        self.source_registry: dict[str, IFunc] = {}
        self.am_table: dict[str, Callable[["PE", bytes], None]] = {}
        self.caps: dict[str, np.ndarray] = {}
        self.completed: list[np.ndarray] = []
        self.stats = PEStats()
        self.caching_enabled = True  # benchmark switch: uncached mode
        self._seq = 0
        self._region_dev: dict[str, tuple[int, jax.Array]] = {}
        self._region_ver: dict[str, int] = {}

    # --- local state ------------------------------------------------------
    def register_region(self, name: str, arr: np.ndarray) -> None:
        self.endpoint.register_region(name, arr)
        self._region_ver[name] = self._region_ver.get(name, 0) + 1

    def region(self, name: str) -> np.ndarray:
        return self.endpoint.regions[name]

    def _region_device(self, name: str) -> jax.Array:
        """Device-resident view of a region, cached until the region is
        rewritten (read-mostly shards stay resident, like RDMA-registered
        memory staying pinned)."""
        ver = self._region_ver.get(name, 0)
        hit = self._region_dev.get(name)
        if hit is not None and hit[0] == ver:
            return hit[1]
        dev = jax.device_put(self.endpoint.regions[name])
        self._region_dev[name] = (ver, dev)
        return dev

    def _write_region(self, name: str, value: np.ndarray) -> None:
        np.copyto(self.endpoint.regions[name], value)
        self._region_ver[name] = self._region_ver.get(name, 0) + 1

    def register_cap(self, name: str, arr: np.ndarray) -> None:
        self.caps[name] = np.asarray(arr)

    # --- source side --------------------------------------------------------
    def register_source(self, ifunc: IFunc) -> IFunc:
        self.source_registry[ifunc.name] = ifunc
        return ifunc

    def _resolve_source(self, name: str) -> IFunc:
        got = self.source_registry.get(name)
        if got is None:
            if self.toolchain is None:
                raise ProtocolError(f"{self.name}: no source artifact for {name!r}")
            got = self.register_source(self.toolchain.lookup(name))
        return got

    def send_ifunc(self, dst: str, name: str, payload: np.ndarray | bytes) -> int:
        """Create and PUT an ifunc message; returns wire bytes sent."""
        ifunc = self._resolve_source(name)
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        self._seq += 1
        frame = ifunc.make_frame(pay, seq=self._seq)
        return self._put_frame(dst, frame)

    def send_am(self, dst: str, name: str, payload: np.ndarray | bytes) -> int:
        """Active Message baseline: payload-only frame, handler pre-deployed."""
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        self._seq += 1
        frame = Frame(kind=FrameKind.ACTIVE_MESSAGE, name=name, payload=pay, seq=self._seq)
        wire = frame.wire_bytes(cached=True)  # AM never carries code
        self.fabric.put(self.name, dst, wire)
        return len(wire)

    def _put_frame(self, dst: str, frame: Frame) -> int:
        cached = self.caching_enabled and self.sender_cache.check_and_add(
            dst, frame.name, len(frame.code)
        )
        wire = frame.wire_bytes(cached=cached)
        self.fabric.put(self.name, dst, wire)
        return len(wire)

    # --- target side --------------------------------------------------------
    def poll(self, max_msgs: int | None = None) -> int:
        """Drain the endpoint buffer, installing and invoking arrivals.

        This is the paper's 'UCX ifunc polling function' — ideally called
        from a daemon thread; tests and the single-core benchmarks call it
        from a round-robin scheduler (core.cluster).
        """
        n = 0
        for buf in self.endpoint.drain():
            self._handle(bytes(buf))
            n += 1
            self.stats.msgs += 1
            if max_msgs is not None and n >= max_msgs:
                break
        return n

    def _handle(self, buf: bytes) -> None:
        hdr = peek_header(buf)
        if hdr is None:
            raise ProtocolError("short frame")
        if hdr.kind == FrameKind.ACTIVE_MESSAGE:
            frame = unpack(buf, has_code=False)
            handler = self.am_table.get(frame.name)
            if handler is None:
                raise ProtocolError(f"{self.name}: no AM handler {frame.name!r}")
            self.stats.am_handled += 1
            handler(self, frame.payload)
            return
        # ifunc path: does this wire carry code? (sender truncates iff it
        # believes we have it; len tells the truth, the registry must agree)
        has_code = len(buf) >= hdr.full_total and hdr.code_len > 0
        if not self.target_cache.has_name(hdr.name):
            if not has_code:
                raise ProtocolError(
                    f"{self.name}: truncated frame for unregistered ifunc "
                    f"{hdr.name!r} (stale sender cache — was this PE restarted?)"
                )
            frame = unpack(buf, has_code=True)
            exe = self._install(frame)
        else:
            frame = unpack(buf, has_code=has_code)
            exe = self.target_cache.lookup(hdr.name)
            assert exe is not None
        self._invoke(exe, frame.payload)

    def _install(self, frame: Frame) -> CachedExecutable:
        """Extract slice -> (ORC-)JIT -> digest cache (Sec. III-C/D).

        A digest hit skips compilation entirely (ORC-JIT's internal symbol
        cache, which the paper observed makes re-JIT of already-seen code
        free) — only the name registration is new."""
        hit = self.target_cache.lookup_digest(frame.digest.hex())
        if hit is not None:
            exe = CachedExecutable(
                name=frame.name,
                digest=hit.digest,
                fn=hit.fn,
                in_avals=hit.in_avals,
                deps=frame.deps or hit.deps,
                kind=int(frame.kind),
                extras=dict(hit.extras),
            )
            self.target_cache.install(exe, jit_ms=0.0)
            self.stats.ifunc_installs += 1
            return exe
        from .bitcode import BitcodeSlice  # noqa: F401  (documented type)

        fat = FatBitcode.from_bytes(frame.code)
        if frame.kind == FrameKind.BINARY:
            # binary code is ISA/uarch-specific: exact triple or bust
            if self.triple not in fat.slices:
                raise ISAMismatch(
                    f"binary ifunc {frame.name!r} built for {fat.triples()} "
                    f"cannot run on {self.triple!r} (Sec. III-B problem; "
                    f"ship bitcode instead)"
                )
            blob = fat.slices[self.triple]
        else:
            blob = fat.extract(self.triple).blob
        t0 = time.perf_counter()
        exported = jax.export.deserialize(blob)
        compiled = jax.jit(exported.call).lower(*exported.in_avals).compile()
        jit_ms = (time.perf_counter() - t0) * 1e3
        abi = "pure"
        for d in frame.deps:
            if d.startswith("abi:"):
                abi = d.split(":", 1)[1]
        exe = CachedExecutable(
            name=frame.name,
            digest=frame.digest.hex(),
            fn=compiled,
            in_avals=tuple(exported.in_avals),
            deps=frame.deps,
            kind=int(frame.kind),
            extras={"code": frame.code, "abi": abi},
        )
        self.target_cache.install(exe, jit_ms=jit_ms)
        self.stats.ifunc_installs += 1
        self.stats.jit_ms_total += jit_ms
        return exe

    # --- invoke -------------------------------------------------------------
    def _decode_payload(self, exe: CachedExecutable, payload: bytes) -> np.ndarray:
        aval = exe.in_avals[0]
        arr = np.frombuffer(payload, dtype=aval.dtype)
        return arr.reshape(aval.shape)

    def _dep_args(self, exe: CachedExecutable) -> list[Any]:
        args: list[Any] = []
        for d in exe.deps:
            tag, _, val = d.partition(":")
            if tag == "region":
                args.append(self._region_device(val))
            elif tag == "cap":
                args.append(self.caps[val])
        return args

    def _dep_named(self, exe: CachedExecutable, tag: str) -> str | None:
        for d in exe.deps:
            t, _, val = d.partition(":")
            if t == tag:
                return val
        return None

    def _invoke(self, exe: CachedExecutable, payload: bytes) -> None:
        self.stats.invokes += 1
        pay = self._decode_payload(exe, payload)
        args = self._dep_args(exe)
        out = exe.fn(pay, *args)
        abi = exe.extras.get("abi", "pure")
        if abi == "update":
            region = self._dep_named(exe, "region")
            assert region is not None, "update ABI requires a region dep"
            self._write_region(region, np.asarray(out))
        elif abi == "xrdma":
            self._apply_action(exe, np.asarray(out))
        else:  # pure
            self.completed.append(np.asarray(out))

    def _apply_action(self, exe: CachedExecutable, action: np.ndarray) -> None:
        """The fixed X-RDMA action protocol (see module docstring)."""
        code = int(action[0])
        dst_idx = int(action[1])
        plen = int(action[2])
        pay = np.ascontiguousarray(action[3 : 3 + plen])
        if code == A_DONE:
            self.completed.append(pay)
            return
        dst = self.peers[dst_idx]
        if code == A_FORWARD:
            self.stats.forwards += 1
            self._seq += 1
            frame = Frame(
                kind=FrameKind(exe.kind),
                name=exe.name,
                payload=pay.tobytes(),
                code=exe.extras["code"],
                deps=exe.deps,
                digest=bytes.fromhex(exe.digest),
                seq=self._seq,
            )
            self._put_frame(dst, frame)
        elif code == A_RETURN:
            self.stats.returns += 1
            target = self._dep_named(exe, "returns")
            assert target is not None, "RETURN requires a returns: dep"
            self.send_ifunc(dst, target, pay)
        elif code == A_SPAWN:
            self.stats.spawns += 1
            target = self._dep_named(exe, "spawn")
            assert target is not None, "SPAWN requires a spawn: dep"
            self.send_ifunc(dst, target, pay)
        else:
            raise ProtocolError(f"bad action code {code}")
