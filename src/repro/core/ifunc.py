"""ifuncs: injected functions — code that travels with the message.

.. note::
   This module is a **stable re-export facade**.  The runtime it used to
   hold in one file now lives in the layered package :mod:`repro.core.pe`:

   * :mod:`repro.core.pe.source`    — :class:`IFunc`, :class:`Toolchain`
   * :mod:`repro.core.pe.wire`      — frame egress, batching queues,
     coalesced flush, rendezvous staging, credit-based flow control
   * :mod:`repro.core.pe.codecache` — install + digest validation +
     bucketed batched executables
   * :mod:`repro.core.pe.exec`      — invoke, masked-scan update ABI, the
     X-RDMA action protocol (and its ``A_*`` constants)
   * :mod:`repro.core.pe.progress`  — the :class:`ProgressEngine` poll
     loop: priority lanes, per-poll budget, credit return
   * :mod:`repro.core.pe.cq`        — :class:`CompletionQueue`,
     :class:`GatherFuture`
   * :mod:`repro.core.pe.pe`        — the thin :class:`PE` facade

   Every name importable from here before the split stays importable from
   here (``from repro.core.ifunc import PE, CompletionQueue, GatherFuture,
   IFunc`` is covered by tests/test_layering.py); new code should import
   from :mod:`repro.core` or the specific layer.

Source side, an :class:`IFunc` couples an entry function (a pure JAX
function) with its fat-bitcode archive (``jax.export`` blobs for every
toolchain target, Sec. III-C) and its dependency list (Sec. III-C
``.deps``).  Target side, a :class:`PE` (processing element) polls its
endpoint, installs arriving code (extract slice -> deserialize ->
target-side JIT -> digest cache) and invokes it.  The ABI the runtime and
injected code meet at — the action protocol, the ``update``/``xrdma``/
``propagate`` conventions, the dependency tags — is documented in
:mod:`repro.core.pe.exec` and :mod:`repro.core.pe.source`.
"""

from __future__ import annotations

from .frame import ProtocolError  # historical re-export (pre-PR 2 home)
from .pe import (
    ACTION_WIDTH,
    A_DONE,
    A_FORWARD,
    A_NOP,
    A_PUBLISH,
    A_RETURN,
    A_SPAWN,
    CompletionQueue,
    GatherFuture,
    IFunc,
    ISAMismatch,
    PE,
    PEStats,
    RNDV_STAGING_DEPTH,
    Toolchain,
)

__all__ = [
    "ACTION_WIDTH",
    "A_DONE",
    "A_FORWARD",
    "A_NOP",
    "A_PUBLISH",
    "A_RETURN",
    "A_SPAWN",
    "CompletionQueue",
    "GatherFuture",
    "IFunc",
    "ISAMismatch",
    "PE",
    "PEStats",
    "ProtocolError",
    "RNDV_STAGING_DEPTH",
    "Toolchain",
]
