"""DAPC miniapp + GBPC baseline (paper Secs. IV-C/IV-D).

The pointer table is a random permutation cycle over ``n_entries`` int32
entries, split into even shards across the servers ("indexed using the
server number first": owner(addr) = addr // shard_size).  Three execution
modes, as in the paper:

* ``bitcode`` — X-RDMA Chaser ifunc, fat-bitcode representation.
* ``binary``  — same Chaser, single-triple binary representation.
* ``am``      — Active Messages: pre-deployed python handlers, payload-only
  frames (the paper's evaluation baseline).

plus ``gbpc(...)`` — the RDMA-GET baseline: the client chases by itself,
one one-sided READ round-trip per hop (move-data-to-compute).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .cluster import Cluster
from .dataplane import DataPlaneConfig
from .frame import FrameKind
from .pe import PE
from .propagate import PropagationConfig
from .transport import WireReportMixin
from .xrdma import make_chaser, make_return_result

RESULT_SENTINEL = -1


def make_chain(n_entries: int, seed: int = 0) -> np.ndarray:
    """A single random cycle: table[i] = successor of i (int32)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_entries)
    table = np.empty(n_entries, np.int32)
    table[perm] = np.roll(perm, -1)
    return table


def chase_ref(table: np.ndarray, start: int, depth: int) -> int:
    """Pure-numpy oracle for any chase implementation."""
    a = int(start)
    for _ in range(depth):
        a = int(table[a])
    return a


@dataclass
class ChaseReport(WireReportMixin):
    results: np.ndarray
    rounds: int
    puts: int
    gets: int
    put_bytes: int
    get_bytes: int
    modeled_us: float
    invokes: int = 0  # XLA dispatches across all PEs (batched dispatch = 1)
    coalesced_frames: int = 0  # PUTs that carried >1 payload
    coalesced_payloads: int = 0  # payloads carried inside those PUTs
    region_puts: int = 0  # one-sided slab-write batches (zero-copy RETURNs)
    region_put_bytes: int = 0  # data + doorbell bytes those writes carried
    hop_frames: int = 0  # PUBLISH hop frames (tree code distribution)
    wire_bytes_by_kind: dict = field(default_factory=dict)


class PointerChaseApp:
    """Wires a Cluster with a sharded pointer table and runs chases."""

    def __init__(self, cluster: Cluster, n_entries: int, max_slots: int = 256, seed: int = 0):
        if n_entries % cluster.n_servers:
            raise ValueError("n_entries must divide evenly across servers")
        self.cluster = cluster
        self.table = make_chain(n_entries, seed)
        self.n_entries = n_entries
        self.max_slots = max_slots
        self.shard_size = n_entries // cluster.n_servers
        # distribute shards + metadata to servers
        for i, pe in enumerate(cluster.servers):
            lo = i * self.shard_size
            pe.register_region("table_shard", self.table[lo : lo + self.shard_size].copy())
            pe.register_cap(
                "shard_meta", np.array([i, self.shard_size, cluster.n_servers], np.int32)
            )
        # client result buffer: slots + completion counter
        cluster.client.register_region("results", np.zeros(max_slots + 1, np.int32))
        # toolchain artifacts (the "directory Three-Chains can locate")
        tc = cluster.toolchain
        tc.publish(make_chaser(self.shard_size))
        tc.publish(make_return_result(max_slots))
        tc.publish(
            make_chaser(
                self.shard_size,
                targets=(cluster.servers[0].triple,) if cluster.servers else ("cpu-host",),
                kind=FrameKind.BINARY,
                name="chaser_bin",
            )
        )
        # AM mode: handlers must be pre-deployed on every PE (the baseline's
        # defining constraint)
        for pe in cluster.servers:
            pe.am_table["chase"] = _chase_am_handler
        cluster.client.am_table["chase_result"] = _chase_result_am_handler

    # ----------------------------------------------------------------- util
    def owner(self, addr: int) -> int:
        return int(addr) // self.shard_size

    def _reset_results(self) -> np.ndarray:
        res = self.cluster.client.region("results")
        res.fill(0)
        res[: self.max_slots] = RESULT_SENTINEL
        # in-place mutation under the registration: invalidate any device-
        # resident mirror so the first RETURN fold reads the reset state
        self.cluster.client.endpoint.touch_region("results")
        return res

    def _finish(self, n: int, rounds: int, invokes0: int = 0) -> ChaseReport:
        st = self.cluster.fabric.stats
        res = self.cluster.client.region("results")[:n].copy()
        return ChaseReport(
            results=res,
            rounds=rounds,
            invokes=self._total_invokes() - invokes0,
            **st.report_kwargs(),
        )

    def _total_invokes(self) -> int:
        return sum(pe.stats.invokes for pe in self.cluster.pes())

    # ----------------------------------------------------------------- DAPC
    def dapc(
        self,
        starts: np.ndarray,
        depth: int,
        mode: str = "bitcode",
        batching: bool = False,
        dataplane: DataPlaneConfig | None = None,
        propagation: PropagationConfig | None = None,
    ) -> ChaseReport:
        """Launch one X-RDMA Chaser per start and run to completion.

        ``batching=True`` switches the whole cluster onto the batched
        runtime: all launches are enqueued and flushed as one coalesced PUT
        per destination, every PE retires same-type arrivals in one XLA
        dispatch, and FORWARD/RETURN bursts coalesce per destination.  The
        per-message path (``batching=False``, the default) is kept as the
        A/B baseline.  ``dataplane`` selects the RETURN protocol for this
        run (framed / zero-copy slab writes / rendezvous); the chase
        result buffer doubles as the zero-copy slab, so the completion
        predicate (the counter word) is identical on every path.
        ``propagation`` switches code distribution from the implicit flat
        push (each launch's first contact carries the code) to a tree
        multicast ahead of the launches — fewer client-side code sends,
        identical results.
        """
        starts = np.asarray(starts, np.int32)
        n = len(starts)
        if n > self.max_slots:
            raise ValueError("too many concurrent chases")
        cl = self.cluster
        client = cl.client
        self._reset_results()
        cl.fabric.stats.reset()
        cl.set_batching(batching)
        cl.set_dataplane(dataplane)
        invokes0 = self._total_invokes()
        name = {"bitcode": "chaser", "binary": "chaser_bin"}.get(mode)
        if propagation is not None and name is not None:
            cl.distribute_code(name, propagation)
        results = cl.client.region("results")
        if mode == "am":
            for slot, start in enumerate(starts):
                payload = np.array([start, depth, cl.client_index, slot], np.int32)
                client.send_am(f"server{self.owner(start)}", "chase", payload)
        elif name is not None:
            for slot, start in enumerate(starts):
                payload = np.array([start, depth, cl.client_index, slot], np.int32)
                client.send_ifunc(f"server{self.owner(start)}", name, payload)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        client.flush()
        try:
            rounds = cl.run_until(lambda: results[self.max_slots] >= n)
        finally:
            # don't leak batched mode or a non-default data plane into later
            # traffic on this cluster: a send after dapc() would queue
            # silently / keep writing slabs nobody is polling
            cl.set_batching(False)
            cl.set_dataplane(None)
        return self._finish(n, rounds, invokes0)

    # ----------------------------------------------------------------- GBPC
    def gbpc(self, starts: np.ndarray, depth: int) -> ChaseReport:
        """RDMA-GET baseline: the client does every hop itself."""
        cl = self.cluster
        self._reset_results()
        cl.fabric.stats.reset()
        invokes0 = self._total_invokes()
        results = cl.client.region("results")
        for slot, start in enumerate(np.asarray(starts, np.int32)):
            a = int(start)
            for _ in range(depth):
                srv = self.owner(a)
                off = (a - srv * self.shard_size) * 4
                data = cl.fabric.get(cl.client.name, f"server{srv}", "table_shard", off, 4)
                a = int(np.frombuffer(data, np.int32)[0])
            results[slot] = a
            results[self.max_slots] += 1
        return self._finish(len(starts), rounds=0, invokes0=invokes0)


# -------------------------------------------------------------- AM handlers
def _chase_am_handler(pe: PE, payload: bytes) -> None:
    """Pre-deployed chase step (the Active Message baseline): identical
    logic to the Chaser ifunc, but as resident code + payload-only frames."""
    addr, depth, requester, slot = np.frombuffer(payload, np.int32)
    shard = pe.region("table_shard")
    shard_id, shard_size, _ = pe.caps["shard_meta"]
    base = int(shard_id) * int(shard_size)
    a, d = int(addr), int(depth)
    while d > 0 and a // int(shard_size) == int(shard_id):
        a = int(shard[a - base])
        d -= 1
    if d == 0:
        pe.send_am(pe.peers[int(requester)], "chase_result", np.array([slot, a], np.int32))
    else:
        pe.send_am(
            pe.peers[a // int(shard_size)],
            "chase",
            np.array([a, d, requester, slot], np.int32),
        )


def _chase_result_am_handler(pe: PE, payload: bytes) -> None:
    slot, value = np.frombuffer(payload, np.int32)
    res = pe.region("results")
    res[slot] = value
    res[-1] += 1
