"""ifunc message frames (paper Figs. 2 & 3) and the truncation protocol.

Layout (bitcode mode, Fig. 3)::

    HEADER | PAYLOAD | MAGIC | CODE | DEPS | MAGIC

The frame is a single contiguous byte block. The *full* frame is always
constructed; the sender controls what actually travels by passing a different
*size* to the PUT (never by editing the frame): a cached send stops after the
first MAGIC. The MAGIC sentinels double as delivery detection for one-sided
PUTs — the receiver polls its buffer and considers the message delivered when
the expected trailing MAGIC is present (Sec. III-D).

Header fields::

    magic4  version  kind  flags  name_len  payload_len  code_len  deps_len
    digest(32B)  ack(4B) seq(4B)  name(name_len B)

The trailing 8-byte word is the reliability layer's channel state: the low
u32 is the sender-assigned per-peer sequence number, the high u32 a
piggybacked cumulative ACK (every seq <= ack from the *receiver's* stream
has been ingested by the sender of this frame).  Both are 0 when the
reliability layer is off — the pre-reliability wire format, bit-for-bit,
at zero added bytes when it is on.

Multi-payload frames (coalescing)
---------------------------------
A frame whose ``flags`` carry :attr:`FrameFlags.BATCH` packs N payloads of
the *same* ifunc type behind one header and (at most) one code section::

    HEADER | count(varint) item(varint) [len0..lenN-1(varint)] payload0 .. payloadN-1 | MAGIC | CODE | DEPS | MAGIC
            `--------------------------- PAYLOAD section ---------------------------'

The batch sub-header is a varint offset table: ``count`` then ``item``.
``item > 0`` is the compressed uniform case — every payload is ``item``
bytes, no per-payload table (2-6 bytes total, vs the 8-byte fixed
sub-header it replaced).  ``item == 0`` marks the ragged form: ``count``
varint lengths follow, one per payload (the scatter-gather offset table).
The truncation protocol is unchanged — the PAYLOAD section (including
the sub-header) sits before the first MAGIC, so a cached coalesced send
is still a prefix PUT — and the wire model charges one ``alpha_us`` for
all N payloads, which is the whole point: per-message latency amortizes
across a burst to one peer.  :func:`coalesce` builds such a frame from
same-type frames and :func:`split_payloads` recovers the individual
payloads on the target.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from enum import IntEnum

HDR_MAGIC = b"3CHN"
MAGIC = b"\xabMAGIC\xba\x00"  # 8-byte delivery sentinel
MAGIC_LEN = len(MAGIC)

_HDR_FMT = "<4sBBBxHxxIII32sQ"
_HDR_LEN = struct.calcsize(_HDR_FMT)


class ProtocolError(RuntimeError):
    """A peer violated the Three-Chains wire protocol (bad frame, stale
    cache, unknown handler...).  Defined here, at the bottom layer, so both
    the frame parser and the PE runtime raise the same family."""


class CorruptFrame(ProtocolError, ValueError):
    """Garbage bytes where a frame should be.  Also a ValueError: callers
    that validated frames before ProtocolError existed keep working."""


class FrameKind(IntEnum):
    BITCODE = 1  # fat-bitcode ifunc (Sec. III-C)
    BINARY = 2  # binary ifunc (Sec. III-B): single-triple, no target JIT
    ACTIVE_MESSAGE = 3  # pre-deployed handler, payload-only (baseline)
    GET_RESPONSE = 4  # transport-internal: RDMA GET reply
    RNDV = 5  # rendezvous descriptor: 16B control, data pulled by GET
    ACK = 6  # standalone cumulative ACK (header-only; reliability layer)


class FrameFlags(IntEnum):
    NONE = 0
    RESULT = 1  # carries a ReturnResult payload
    BATCH = 2  # PAYLOAD section is a multi-payload pack (see module docstring)
    HOP = 4  # PAYLOAD section starts with a propagation hop header (PUBLISH)
    EXPRESS = 8  # latency-class hint: drain via the control lane when
    # self-contained (multi-tenant QoS; flag travels in the existing
    # flags byte, so pre-QoS receivers parse it unchanged)


# 16-byte rendezvous descriptor: [src_peer_index, token, data_nbytes, reserved].
# The receiver reconstructs the staging region name from (src, token) and
# pulls the payload with a one-sided GET — correct when the payload dwarfs
# 2*alpha, and the only RETURN shape whose eager cost grows with size.
RNDV_DESC = struct.Struct("<IIII")
RNDV_DESC_NBYTES = RNDV_DESC.size


def rndv_region(src_name: str, token: int) -> str:
    """Staging-region naming convention shared by both ends of a rendezvous."""
    return f"rndv/{src_name}/{token}"


def pack_rndv(src_idx: int, token: int, nbytes: int) -> bytes:
    """Build one 16-byte rendezvous descriptor (reserved word always 0)."""
    return RNDV_DESC.pack(src_idx, token, nbytes, 0)


def unpack_rndv(desc: bytes) -> tuple[int, int, int]:
    """Parse + validate one rendezvous descriptor -> (src_idx, token,
    nbytes).  Anything that is not exactly one well-formed descriptor —
    truncation, trailing bytes, a set reserved word — is a loud
    :class:`CorruptFrame`, never a silent misparse."""
    if len(desc) != RNDV_DESC.size:
        raise CorruptFrame(
            f"malformed rendezvous descriptor: {len(desc)} bytes "
            f"(want {RNDV_DESC.size})"
        )
    src_idx, token, nbytes, reserved = RNDV_DESC.unpack(desc)
    if reserved != 0:
        raise CorruptFrame("malformed rendezvous descriptor: reserved word set")
    return src_idx, token, nbytes


# ------------------------------------------------------- propagation hops
# A PUBLISH frame (``FrameFlags.HOP``) prefixes its PAYLOAD section with a
# hop header: the recursive-propagation state a re-publishing PE needs to
# keep the multicast a *tree* —
#
#     ttl(u8)  k(u8)  root(u16)  pub_id(u32)  n_path(u16)  pad(2B)
#     path_digest(u64)  path[n_path](u16 each)
#
# ``ttl``    remaining hops this publish may still travel; a frame arriving
#            with ttl == 0 is expired and refused, a PE republishing sends
#            ttl - 1 and stops (silently) once that would hit zero.
# ``k``      tree shape on the wire: 0 = binomial, else k-ary fanout — so a
#            mid-tree PE needs no out-of-band config agreement.
# ``root``   peer index the publish originated at (tree root).
# ``pub_id`` root-chosen id; (code digest, root, pub_id) is the dedup key
#            that makes delivery exactly-once per PE under a fabric that is
#            only at-least-once (and is what breaks forwarding cycles).
# ``path``   peer indices visited so far, root first; a PE that finds its
#            own index here refuses the hop (cycle).  ``path_digest`` is a
#            FNV-1a over (k, root, pub_id, path): truncated or tampered hop
#            headers are rejected before any of their fields are trusted.
_HOP_FMT = struct.Struct("<BBHIH2xQ")
HOP_FIXED_NBYTES = _HOP_FMT.size  # 20
MAX_HOP_PATH = 1024  # sanity bound: longest admissible visited-path


def _fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass(frozen=True)
class HopHeader:
    """Parsed propagation hop state (see wire layout above)."""

    ttl: int
    root: int
    pub_id: int
    path: tuple[int, ...]
    k: int = 0  # 0 = binomial tree, else k-ary fanout

    @property
    def nbytes(self) -> int:
        return HOP_FIXED_NBYTES + 2 * len(self.path)

    def digest(self) -> int:
        body = struct.pack("<BHI", self.k, self.root, self.pub_id)
        body += struct.pack(f"<{len(self.path)}H", *self.path)
        return _fnv1a64(body)

    def child_hop(self, me: int) -> "HopHeader":
        """The header a PE at index ``me`` republishes with: one hop spent,
        itself appended to the visited path."""
        return HopHeader(
            ttl=self.ttl - 1,
            root=self.root,
            pub_id=self.pub_id,
            path=(*self.path, me),
            k=self.k,
        )


def hop_nbytes(n_path: int) -> int:
    return HOP_FIXED_NBYTES + 2 * n_path


def pack_hop(hop: HopHeader) -> bytes:
    if not 0 <= hop.ttl <= 255:
        raise ValueError(f"hop ttl {hop.ttl} out of u8 range")
    if len(hop.path) > MAX_HOP_PATH:
        raise ValueError(f"hop path longer than {MAX_HOP_PATH}")
    head = _HOP_FMT.pack(
        hop.ttl, hop.k, hop.root, hop.pub_id, len(hop.path), hop.digest()
    )
    return head + struct.pack(f"<{len(hop.path)}H", *hop.path)


def unpack_hop(buf: bytes, off: int = 0) -> tuple[HopHeader, int]:
    """Parse one hop header at ``off``; returns (hop, next_off).  Truncated,
    over-long, or digest-mismatched headers raise :class:`CorruptFrame`."""
    if len(buf) < off + HOP_FIXED_NBYTES:
        raise CorruptFrame("corrupt hop header: truncated")
    ttl, k, root, pub_id, n_path, digest = _HOP_FMT.unpack_from(buf, off)
    if n_path > MAX_HOP_PATH:
        raise CorruptFrame(f"corrupt hop header: path length {n_path}")
    end = off + HOP_FIXED_NBYTES + 2 * n_path
    if len(buf) < end:
        raise CorruptFrame("corrupt hop header: truncated path")
    path = struct.unpack_from(f"<{n_path}H", buf, off + HOP_FIXED_NBYTES)
    hop = HopHeader(ttl=ttl, root=root, pub_id=pub_id, path=tuple(path), k=k)
    if hop.digest() != digest:
        raise CorruptFrame("corrupt hop header: path digest mismatch")
    return hop, end


def split_hop(payload: bytes) -> tuple[HopHeader, bytes]:
    """Strip the hop header off a PUBLISH frame's payload section; returns
    (hop, inner payload bytes — possibly empty for a code-only publish)."""
    hop, off = unpack_hop(payload, 0)
    return hop, payload[off:]


# ------------------------------------------------------------------ varint
def uvarint_encode(n: int) -> bytes:
    """LEB128 unsigned varint (u32 range)."""
    if n < 0:
        raise ValueError("uvarint is unsigned")
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def uvarint_decode(buf: bytes, off: int) -> tuple[int, int]:
    """Decode one varint at ``off``; returns (value, next_off).  Truncated
    or over-long (>5 byte) encodings raise :class:`CorruptFrame`."""
    val = shift = 0
    for i in range(5):
        if off + i >= len(buf):
            raise CorruptFrame("corrupt batch frame: truncated varint")
        b = buf[off + i]
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, off + i + 1
        shift += 7
    raise CorruptFrame("corrupt batch frame: over-long varint")


def pack_payloads(payloads: "list[bytes]") -> bytes:
    """Build a BATCH payload section: varint sub-header + concatenated
    payloads (uniform compressed form when sizes agree, offset table
    otherwise)."""
    lens = [len(p) for p in payloads]
    uniform = lens[0] if lens and all(n == lens[0] for n in lens) else 0
    head = uvarint_encode(len(payloads)) + uvarint_encode(uniform)
    if not uniform:
        head += b"".join(uvarint_encode(n) for n in lens)
    return head + b"".join(payloads)


def unpack_payloads(section: bytes) -> "list[bytes]":
    """Inverse of :func:`pack_payloads`; size disagreements are loud."""
    count, off = uvarint_decode(section, 0)
    item, off = uvarint_decode(section, off)
    if count > len(section):  # cheap sanity bound before allocating a table
        raise CorruptFrame("corrupt batch frame: payload count exceeds section")
    if item:
        lens = [item] * count
    else:
        lens = []
        for _ in range(count):
            n, off = uvarint_decode(section, off)
            lens.append(n)
    if len(section) != off + sum(lens):
        raise CorruptFrame("corrupt batch frame: payload section size mismatch")
    out = []
    for n in lens:
        out.append(section[off : off + n])
        off += n
    return out


def batch_subheader_nbytes(section: bytes) -> int:
    """How many bytes of a BATCH payload section are offset-table overhead."""
    count, off = uvarint_decode(section, 0)
    item, off = uvarint_decode(section, off)
    if not item:
        for _ in range(count):
            _, off = uvarint_decode(section, off)
    return off


@dataclass
class Frame:
    """A parsed view of (or recipe for) one contiguous message frame."""

    kind: FrameKind
    name: str  # ifunc type name, e.g. "tsi" / "chaser"
    payload: bytes
    code: bytes = b""  # fat-bitcode archive (or single slice for BINARY)
    deps: tuple[str, ...] = ()
    digest: bytes = b"\x00" * 32  # sha256 of code section
    seq: int = 0  # per-peer sequence number (u32; 0 = unsequenced)
    ack: int = 0  # piggybacked cumulative ACK (u32; 0 = nothing to ack)
    flags: int = FrameFlags.NONE
    version: int = 1
    # local scheduling metadata, never serialized: which tenant's budget
    # this frame charges against (None = untenanted / infrastructure)
    tenant: str | None = None

    @property
    def n_payloads(self) -> int:
        """1 for a plain frame, the packed count for a BATCH frame."""
        if not self.flags & FrameFlags.BATCH:
            return 1
        return uvarint_decode(self.payload, 0)[0]

    # ------------------------------------------------------------------ pack
    def pack(self) -> bytes:
        """Build the full contiguous frame (always includes the code)."""
        name_b = self.name.encode()
        deps_b = "\n".join(self.deps).encode()
        hdr = struct.pack(
            _HDR_FMT,
            HDR_MAGIC,
            self.version,
            int(self.kind),
            int(self.flags),
            len(name_b),
            len(self.payload),
            len(self.code),
            len(deps_b),
            self.digest,
            ((self.ack & 0xFFFFFFFF) << 32) | (self.seq & 0xFFFFFFFF),
        )
        return b"".join(
            [hdr, name_b, self.payload, MAGIC, self.code, deps_b, MAGIC]
        )

    # Sizes for the truncation protocol ------------------------------------
    @property
    def cached_nbytes(self) -> int:
        """Wire size when the target already holds the code: up to MAGIC #1."""
        return _HDR_LEN + len(self.name.encode()) + len(self.payload) + MAGIC_LEN

    @property
    def full_nbytes(self) -> int:
        return (
            self.cached_nbytes
            + len(self.code)
            + len("\n".join(self.deps).encode())
            + MAGIC_LEN
        )

    def wire_bytes(self, cached: bool) -> bytes:
        """What actually goes on the wire. The frame itself is never edited —
        a cached send is a shorter PUT of the same buffer."""
        full = self.pack()
        return full[: self.cached_nbytes] if cached else full

    def kind_breakdown(self, cached: bool) -> dict[str, int]:
        """Attribute this frame's wire bytes across the fabric's byte-kind
        accounting: ifunc payload data vs framing (header, name, sentinels,
        batch sub-header) vs code+deps."""
        payload = len(self.payload)
        if self.flags & FrameFlags.BATCH:
            payload -= batch_subheader_nbytes(self.payload)
        if self.flags & FrameFlags.HOP:
            payload -= unpack_hop(self.payload)[1]  # hop header is framing
        header = self.cached_nbytes - payload
        code = 0 if cached else self.full_nbytes - self.cached_nbytes
        return {"header": header, "payload": payload, "code": code}


# ---------------------------------------------------------------- unpacking
@dataclass
class ParsedHeader:
    kind: FrameKind
    flags: int
    name: str
    payload_len: int
    code_len: int
    deps_len: int
    digest: bytes
    seq: int
    ack: int
    header_len: int  # header + name bytes

    @property
    def cached_total(self) -> int:
        return self.header_len + self.payload_len + MAGIC_LEN

    @property
    def full_total(self) -> int:
        return self.cached_total + self.code_len + self.deps_len + MAGIC_LEN


def peek_header(buf: bytes | bytearray | memoryview) -> ParsedHeader | None:
    """Parse the header if enough bytes have been delivered, else None."""
    if len(buf) < _HDR_LEN:
        return None
    magic4, version, kind, flags, name_len, payload_len, code_len, deps_len, digest, seq_word = struct.unpack_from(
        _HDR_FMT, buf, 0
    )
    if magic4 != HDR_MAGIC:
        raise CorruptFrame("corrupt frame: bad header magic")
    if len(buf) < _HDR_LEN + name_len:
        return None
    try:
        name = bytes(buf[_HDR_LEN : _HDR_LEN + name_len]).decode()
        kind = FrameKind(kind)
    except (UnicodeDecodeError, ValueError) as e:
        raise CorruptFrame(f"corrupt frame: {e}") from None
    return ParsedHeader(
        kind=kind,
        flags=flags,
        name=name,
        payload_len=payload_len,
        code_len=code_len,
        deps_len=deps_len,
        digest=digest,
        seq=seq_word & 0xFFFFFFFF,
        ack=seq_word >> 32,
        header_len=_HDR_LEN + name_len,
    )


def delivery_complete(buf: bytes | bytearray | memoryview, expect_code: bool) -> bool:
    """MAGIC-based delivery detection (receiver side of one-sided PUT).

    ``expect_code`` is decided by the *receiver's own registry*: if it has
    already cached this ifunc type it only waits for the payload sentinel,
    otherwise for the trailing sentinel after CODE|DEPS (Sec. III-D).
    """
    hdr = peek_header(buf)
    if hdr is None:
        return False
    end = hdr.full_total if expect_code else hdr.cached_total
    if len(buf) < end:
        return False
    return bytes(buf[end - MAGIC_LEN : end]) == MAGIC


def unpack(buf: bytes | bytearray | memoryview, has_code: bool) -> Frame:
    """Materialize a Frame from a delivered buffer."""
    hdr = peek_header(buf)
    if hdr is None:
        raise CorruptFrame("corrupt frame: truncated header")
    off = hdr.header_len
    payload = bytes(buf[off : off + hdr.payload_len])
    off += hdr.payload_len
    if bytes(buf[off : off + MAGIC_LEN]) != MAGIC:
        raise CorruptFrame("corrupt frame: bad payload sentinel")
    off += MAGIC_LEN
    code = b""
    deps: tuple[str, ...] = ()
    if has_code:
        code = bytes(buf[off : off + hdr.code_len])
        off += hdr.code_len
        deps_b = bytes(buf[off : off + hdr.deps_len])
        off += hdr.deps_len
        try:
            deps = tuple(d for d in deps_b.decode().split("\n") if d)
        except UnicodeDecodeError as e:
            raise CorruptFrame(f"corrupt frame: undecodable deps ({e})") from None
        if bytes(buf[off : off + MAGIC_LEN]) != MAGIC:
            raise CorruptFrame("corrupt frame: bad code sentinel")
    return Frame(
        kind=hdr.kind,
        name=hdr.name,
        payload=payload,
        code=code,
        deps=deps,
        digest=hdr.digest,
        seq=hdr.seq,
        ack=hdr.ack,
        flags=hdr.flags,
    )


# -------------------------------------------------------------- coalescing
def coalesce(frames: "list[Frame]") -> Frame:
    """Pack N same-ifunc frames into one multi-payload frame.

    All frames must agree on (kind, name, digest) — they are instances of one
    ifunc type — and carry equal-size payloads (the wire format's ragged
    offset table exists, but one ifunc type means one payload aval, so the
    runtime only ever emits the uniform compressed form; a ragged batch here
    is a caller bug).  The code/deps sections come from the first frame that
    has them (every member of a batch shares the same code by construction,
    digest equality enforces it).
    """
    if len(frames) == 1:
        return frames[0]
    if any(f.flags & FrameFlags.HOP for f in frames):
        # each hop frame's PAYLOAD starts with its own per-edge path header;
        # packing them behind one header would splice paths together
        raise ValueError("coalesce: PUBLISH hop frames travel individually")
    head = frames[0]
    item = len(head.payload)
    for f in frames[1:]:
        if (f.kind, f.name, f.digest) != (head.kind, head.name, head.digest):
            raise ValueError("coalesce: frames are not the same ifunc type")
        if len(f.payload) != item:
            raise ValueError("coalesce: ragged payload sizes in one batch")
    carrier = next((f for f in frames if f.code), head)
    return Frame(
        kind=head.kind,
        name=head.name,
        payload=pack_payloads([f.payload for f in frames]),
        code=carrier.code,
        deps=carrier.deps,
        digest=head.digest,
        seq=frames[-1].seq,
        flags=head.flags | FrameFlags.BATCH,
        tenant=head.tenant,
    )


def split_payloads(frame: Frame) -> list[bytes]:
    """Individual payloads of a (possibly multi-payload) frame, in order."""
    if not frame.flags & FrameFlags.BATCH:
        return [frame.payload]
    return unpack_payloads(frame.payload)
