"""Liveness primitive: last-seen heartbeat tracking.

This is the clock-agnostic half of failure detection — track when each
peer was last heard from, declare the silent ones dead after
``max_misses`` intervals.  It lives in ``core`` because the progress
engine's :class:`repro.core.pe.progress.FailureDetector` folds it into
the poll loop (tick-clocked: ``interval_s=1`` tick, every ingested frame
a beat); the wall-clock deployment face (straggler policy, step timers,
the multi-pod monitoring story) stays in :mod:`repro.runtime.monitor`,
which re-exports this class unchanged.
"""

from __future__ import annotations

import time


class HeartbeatMonitor:
    """Tracks last-seen times; a PE missing ``max_misses`` beats is dead."""

    def __init__(self, interval_s: float = 1.0, max_misses: int = 3):
        self.interval_s = interval_s
        self.max_misses = max_misses
        self.last_seen: dict[str, float] = {}
        self.dead: set[str] = set()

    def beat(self, name: str, now: float | None = None) -> None:
        self.last_seen[name] = time.monotonic() if now is None else now
        self.dead.discard(name)

    def check(self, now: float | None = None) -> set[str]:
        """Returns the set of PEs newly declared dead."""
        now = time.monotonic() if now is None else now
        newly = set()
        for name, seen in self.last_seen.items():
            if name in self.dead:
                continue
            if now - seen > self.interval_s * self.max_misses:
                self.dead.add(name)
                newly.add(name)
        return newly
