"""Fault-tolerant distributed runtime: heartbeats, stragglers, elastic
restart-from-checkpoint."""

from .monitor import HeartbeatMonitor, StepTimer, StragglerPolicy
from .driver import TrainDriver, TrainReport

__all__ = [
    "HeartbeatMonitor",
    "StepTimer",
    "StragglerPolicy",
    "TrainDriver",
    "TrainReport",
]
