"""Fault-tolerant distributed runtime: heartbeats, stragglers, elastic
restart-from-checkpoint."""

from .embed_service import (
    EmbedShardService,
    FilterShardService,
    GatherReport,
    GatherRequest,
)
from .monitor import HeartbeatMonitor, StepTimer, StragglerPolicy
from .driver import TrainDriver, TrainReport

__all__ = [
    "EmbedShardService",
    "FilterShardService",
    "GatherReport",
    "GatherRequest",
    "HeartbeatMonitor",
    "StepTimer",
    "StragglerPolicy",
    "TrainDriver",
    "TrainReport",
]
