"""Continuous-batching serving runtime.

A fixed decode batch of ``slots`` rides one compiled ``serve_step``;
requests are admitted into free slots as others complete (continuous
batching).  Admission runs a single-sequence prefill and writes the
prompt's K/V into the slot's stripe of the shared cache; per-slot
positions make the attention masks correct for ragged occupancy (the
attend mask is driven by q_pos/k_valid, which are per-batch-row).

This is the serving analogue of the paper's steady state: the compiled
step is the pre-cached code that never moves again; only tiny per-token
payloads (ids + positions) flow per tick.

Families: dense/MoE/hybrid KV caches and RWKV states both work — the
cache pytree is whatever init_kv_cache returns; slot writes go through
`jax.tree_util` so new cache families inherit scheduling for free.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.zoo import (
    _head,
    forward,
    frontend_len,
    init_kv_cache,
    make_serve_step,
)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int
    out: list[int] = field(default_factory=list)
    slot: int | None = None
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeScheduler:
    def __init__(
        self,
        cfg,
        params,
        slots: int = 4,
        t_max: int = 256,
        seed: int = 0,
        embed_client: Any = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.t_max = t_max
        # serving-tier mode: embedding rows come from a remote shard service
        # (RemoteEmbedClient — CQ futures over the PE fabric) instead of a
        # local table lookup; the compiled steps take the rows as an input
        self.embed_client = embed_client
        fl = frontend_len(cfg, t_max)
        self.cache = init_kv_cache(cfg, slots, t_max, enc_len=fl, dtype=cfg.dtype)
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.active: dict[int, Request] = {}  # slot -> request
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._next_rid = 0
        self._tokens = jnp.zeros((slots, 1), jnp.int32)

        self._step = jax.jit(make_serve_step(cfg, remote_embed=embed_client is not None))
        # single-sequence prefill producing the slot's cache stripe
        def prefill_one(params, tokens, rows=None):
            cache1 = init_kv_cache(cfg, 1, t_max, enc_len=fl, dtype=cfg.dtype)
            batch = {"tokens": tokens}
            if rows is not None:
                batch["token_rows"] = rows
            h, cache1, _ = forward(
                cfg, params, batch, caches=cache1,
                offset=jnp.int32(0), return_hidden=True,
            )
            logits = _head(cfg, params, h[:, -1:, :])[:, -1, :]
            return logits, cache1

        if embed_client is None:
            self._prefill = jax.jit(prefill_one)
        else:
            self._prefill = jax.jit(
                lambda params, tokens, rows: prefill_one(params, tokens, rows)
            )

    # ------------------------------------------------------------------ API
    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        req = Request(self._next_rid, np.asarray(prompt, np.int32), max_new,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _write_slot(self, slot: int, cache1: Any) -> None:
        """Copy a 1-batch cache stripe into slot `slot` of the shared cache
        (dim 1 is batch for every cache leaf: (L, B, ...))."""
        self.cache = jax.tree_util.tree_map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1
            ),
            self.cache,
            cache1,
        )

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            p = len(req.prompt)
            assert p + req.max_new <= self.t_max, "prompt too long for cache"
            if self.embed_client is not None:
                rows = self.embed_client.rows(req.prompt[None])
                logits, cache1 = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None], jnp.asarray(rows)
                )
            else:
                logits, cache1 = self._prefill(self.params, jnp.asarray(req.prompt)[None])
            self._write_slot(slot, cache1)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            req.t_first = time.perf_counter()
            req.slot = slot
            self.pos[slot] = p
            self._tokens = self._tokens.at[slot, 0].set(tok)
            self.active[slot] = req

    def _retire(self) -> None:
        for slot, req in list(self.active.items()):
            if len(req.out) >= req.max_new:
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                del self.active[slot]

    def tick(self) -> int:
        """One scheduler round: admit -> retire satisfied -> one batched
        decode step -> retire.  Returns the number of active sequences
        that advanced.  The early retire matters: admission's prefill
        already appended a token, so a ``max_new=1`` request is satisfied
        before any decode — decoding it anyway would overshoot its budget
        by one token."""
        self._admit()
        self._retire()
        if not self.active:
            return 0
        # ragged positions: one serve_step per distinct position group keeps
        # the compiled step's scalar-offset ABI; groups are usually 1-2 deep
        # because continuous batching keeps slots near lockstep
        groups: dict[int, list[int]] = {}
        for slot in self.active:
            groups.setdefault(int(self.pos[slot]), []).append(slot)
        advanced = 0
        # remote-embed: one row gather covers every group this tick (the
        # step input is the full (slots, 1) token batch either way)
        step_rows = None
        if self.embed_client is not None:
            step_rows = jnp.asarray(
                self.embed_client.rows(np.asarray(self._tokens))
            )
        for pos, slots in sorted(groups.items()):
            if step_rows is not None:
                logits, cache = self._step(
                    self.params, self.cache, self._tokens, jnp.int32(pos), step_rows
                )
            else:
                logits, cache = self._step(
                    self.params, self.cache, self._tokens, jnp.int32(pos)
                )
            # keep updates only for this group's slots
            mask = np.zeros(self.slots, bool)
            mask[slots] = True
            m = jnp.asarray(mask)

            def merge(new, old):
                bm = m.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(bm, new, old)

            self.cache = jax.tree_util.tree_map(merge, cache, self.cache)
            toks = np.asarray(jnp.argmax(logits, -1), np.int32)
            for slot in slots:
                req = self.active[slot]
                req.out.append(int(toks[slot]))
                self.pos[slot] += 1
                self._tokens = self._tokens.at[slot, 0].set(int(toks[slot]))
                advanced += 1
        self._retire()
        return advanced

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.tick()
        return self.finished
