"""Health monitoring: heartbeats and straggler detection.

On a real multi-pod deployment each host process runs a heartbeat thread
against the coordinator (jax.distributed's liveness check plays this role
natively); here the monitor is exercised in-process against the simulated
fabric's PEs — the *code paths* (miss-count thresholds, dead-set
propagation, elastic trigger) are the production ones, which is what the
tests pin down.

Straggler policy: per-step wall-time EWMA; a host whose step time exceeds
``factor`` x the fleet median for ``patience`` consecutive steps is marked
a persistent straggler, which triggers the same elastic path as a death
(drop the host, restore, re-shard) — at 1000+ nodes a 1.7x straggler
costs more than the restart it takes to shed it.
"""

from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass


class HeartbeatMonitor:
    """Tracks last-seen times; a PE missing ``max_misses`` beats is dead."""

    def __init__(self, interval_s: float = 1.0, max_misses: int = 3):
        self.interval_s = interval_s
        self.max_misses = max_misses
        self.last_seen: dict[str, float] = {}
        self.dead: set[str] = set()

    def beat(self, name: str, now: float | None = None) -> None:
        self.last_seen[name] = time.monotonic() if now is None else now
        self.dead.discard(name)

    def check(self, now: float | None = None) -> set[str]:
        """Returns the set of PEs newly declared dead."""
        now = time.monotonic() if now is None else now
        newly = set()
        for name, seen in self.last_seen.items():
            if name in self.dead:
                continue
            if now - seen > self.interval_s * self.max_misses:
                self.dead.add(name)
                newly.add(name)
        return newly


@dataclass
class StragglerPolicy:
    factor: float = 1.7  # x median step time
    patience: int = 5  # consecutive slow steps before acting
    ewma: float = 0.5


class StepTimer:
    """Per-host step-time EWMA + straggler detection."""

    def __init__(self, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.t: dict[str, float] = {}
        self.slow_streak: dict[str, int] = defaultdict(int)

    def record(self, host: str, step_s: float) -> None:
        a = self.policy.ewma
        self.t[host] = step_s if host not in self.t else a * step_s + (1 - a) * self.t[host]

    def median(self) -> float:
        vals = sorted(self.t.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> set[str]:
        med = self.median()
        if med <= 0:
            return set()
        out = set()
        for host, t in self.t.items():
            if t > self.policy.factor * med:
                self.slow_streak[host] += 1
                if self.slow_streak[host] >= self.policy.patience:
                    out.add(host)
            else:
                self.slow_streak[host] = 0
        return out
