"""Health monitoring: heartbeats and straggler detection.

.. deprecated::
   ``HeartbeatMonitor`` lives in :mod:`repro.core.liveness`; this module
   is kept as a re-export shim for deployment-facing imports and will not
   grow new liveness features — import from ``repro.core.liveness`` in
   new code.  ``StragglerPolicy``/``StepTimer`` still live here (they are
   training-loop policy, not fabric liveness).

On a real multi-pod deployment each host process runs a heartbeat thread
against the coordinator (jax.distributed's liveness check plays this role
natively); here the monitor is exercised in-process against the simulated
fabric's PEs — the *code paths* (miss-count thresholds, dead-set
propagation, elastic trigger) are the production ones, which is what the
tests pin down.

Straggler policy: per-step wall-time EWMA; a host whose step time exceeds
``factor`` x the fleet median for ``patience`` consecutive steps is marked
a persistent straggler, which triggers the same elastic path as a death
(drop the host, restore, re-shard) — at 1000+ nodes a 1.7x straggler
costs more than the restart it takes to shed it.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

# The liveness primitive moved into core (the progress engine's failure
# detector is built on it; core must not import runtime).  Re-exported
# here unchanged for the deployment-facing monitoring surface.
from repro.core.liveness import HeartbeatMonitor

__all__ = ["HeartbeatMonitor", "StragglerPolicy", "StepTimer"]


@dataclass
class StragglerPolicy:
    factor: float = 1.7  # x median step time
    patience: int = 5  # consecutive slow steps before acting
    ewma: float = 0.5


class StepTimer:
    """Per-host step-time EWMA + straggler detection."""

    def __init__(self, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.t: dict[str, float] = {}
        self.slow_streak: dict[str, int] = defaultdict(int)

    def record(self, host: str, step_s: float) -> None:
        a = self.policy.ewma
        self.t[host] = step_s if host not in self.t else a * step_s + (1 - a) * self.t[host]

    def median(self) -> float:
        vals = sorted(self.t.values())
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> set[str]:
        med = self.median()
        if med <= 0:
            return set()
        out = set()
        for host, t in self.t.items():
            if t > self.policy.factor * med:
                self.slow_streak[host] += 1
                if self.slow_streak[host] >= self.policy.patience:
                    out.add(host)
            else:
                self.slow_streak[host] = 0
        return out
