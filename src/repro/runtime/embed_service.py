"""Embedding-shard serving over the X-RDMA Gather substrate.

The serving shape DOLMA calls data-object-level disaggregation: a large
embedding (or KV) table lives row-sharded across server PEs, and clients
stream small key-batches at it.  The move-data-to-compute baseline GETs
every row individually (one RDMA round trip per key); the X-RDMA path
ships the Gatherer once, then each request is one tiny key-frame to the
first owner, partial resolution next to every shard it touches, and
partial RETURNs racing back into the requester's completion queue.

:class:`EmbedShardService` is the continuous-batching scheduler for that
substrate, shaped like :class:`repro.runtime.serving.ServeScheduler`:
requests queue, admit into free completion-queue slots as others retire,
and many gathers overlay in flight.  Under ``batching=True`` the whole
pipeline rides PR 1's coalesced-frame / single-dispatch runtime: one PUT
per (destination, tick) carrying every key-frame, one XLA dispatch per
(PE, tick) resolving every arrived request, one masked-scan dispatch
folding every partial RETURN into the queue region.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    Cluster,
    CompletionQueue,
    DataPlaneConfig,
    GatherFuture,
    PropagationConfig,
)
from repro.core.transport import WireReportMixin
from repro.core.xrdma import (
    make_filter,
    make_filter_return,
    make_gather_return,
    make_gatherer,
)


def ragged_batches(
    vocab: int, n_requests: int, n_keys: int, seed: int
) -> list[np.ndarray]:
    """The canonical request mix for benchmarks/tests/examples: ``n_requests``
    batches of 1..``n_keys`` uniform-random row ids (one shared definition so
    every consumer exercises the same workload shape)."""
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, vocab, rng.integers(1, n_keys + 1)).astype(np.int32)
        for _ in range(n_requests)
    ]


@dataclass
class GatherRequest:
    rid: int
    keys: np.ndarray  # (n,) int32 real keys, n <= n_keys
    rows: np.ndarray | None = None  # (n, D) float32 result
    future: GatherFuture | None = None
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0
    valid: np.ndarray | None = None  # (n,) bool; None = all positions valid
    degraded: bool = False  # completed partially (an owner died)
    resubmits: int = 0  # deadline-driven re-submissions of this request
    # --- multi-tenant QoS (set by the serving tier's router) ---
    tenant: str | None = None  # whose credit budget the frames charge
    express: bool = False  # control-lane drain priority at the servers
    slot_quota: int = 0  # max CQ slots this tenant may hold (0 = uncapped)
    t_admit: float = 0.0  # when the request last entered the fabric


@dataclass
class GatherReport(WireReportMixin):
    """Per-run accounting, the gather sibling of ChaseReport."""

    results: list[np.ndarray]
    rounds: int
    puts: int
    gets: int
    put_bytes: int
    get_bytes: int
    modeled_us: float
    invokes: int = 0  # XLA dispatches across all PEs (batched dispatch = 1)
    coalesced_frames: int = 0
    coalesced_payloads: int = 0
    region_puts: int = 0  # one-sided slab-write batches (zero-copy RETURNs)
    region_put_bytes: int = 0  # data + doorbell bytes those writes carried
    hop_frames: int = 0  # PUBLISH hop frames (tree code distribution)
    wire_bytes_by_kind: dict = field(default_factory=dict)


class EmbedShardService:
    """Continuous-batching embedding-shard service on a PE cluster."""

    #: The pushdown operator this service ships and dispatches on.  The
    #: predicate-pushdown sibling (:class:`FilterShardService`) overrides
    #: these plus :meth:`_publish_ops`/:meth:`_request_body`; everything
    #: else — admission, recovery, retirement, reporting — is shared.
    op_name = "gatherer"
    return_name = "gather_return"

    def __init__(
        self,
        cluster: Cluster,
        vocab: int,
        dim: int,
        n_keys: int = 8,
        max_slots: int = 64,
        seed: int = 0,
        table: np.ndarray | None = None,
        strict_recovery: bool = False,
    ) -> None:
        if vocab % cluster.n_servers:
            raise ValueError("vocab must divide evenly across servers")
        self.cluster = cluster
        self.vocab = vocab
        self.dim = dim
        self.n_keys = n_keys
        self.max_slots = max_slots
        # strict_recovery: resubmit-budget exhaustion raises (after the
        # recovery sweep completes) instead of silently degrading
        self.strict_recovery = strict_recovery
        self.rows_per_shard = vocab // cluster.n_servers
        if table is None:
            rng = np.random.default_rng(seed)
            table = rng.standard_normal((vocab, dim)).astype(np.float32)
        self.table = np.asarray(table, np.float32)
        assert self.table.shape == (vocab, dim)
        # shards + metadata to the servers (rows stay put forever after)
        for i, pe in enumerate(cluster.servers):
            lo = i * self.rows_per_shard
            pe.register_region(
                "embed_shard", self.table[lo : lo + self.rows_per_shard].copy()
            )
            pe.register_cap(
                "gather_meta",
                np.array([i, self.rows_per_shard, cluster.n_servers], np.int32),
            )
        # toolchain artifacts (code travels on first contact, then caches)
        self._publish_ops()
        self.cq = CompletionQueue(
            cluster.client, shape=(n_keys, dim), dtype=np.float32,
            max_slots=max_slots,
        )
        self.queue: deque[GatherRequest] = deque()
        self.active: dict[int, GatherRequest] = {}  # slot -> request
        self.finished: list[GatherRequest] = []
        self._next_rid = 0
        self.batching = False
        self.ticks = 0  # scheduler rounds driven; also the CQ deadline clock

    # ------------------------------------------------------------------ util
    def owner(self, key: int) -> int:
        return int(key) // self.rows_per_shard

    def _pad(self, keys: np.ndarray) -> np.ndarray:
        padded = np.full(self.n_keys, -1, np.int32)
        padded[: len(keys)] = keys
        return padded

    def _publish_ops(self) -> None:
        """Publish this service's pushdown operator pair to the toolchain."""
        self.cluster.toolchain.publish(
            make_gatherer(
                self.rows_per_shard, self.cluster.n_servers, self.n_keys, self.dim
            )
        )
        self.cluster.toolchain.publish(
            make_gather_return(self.max_slots, self.n_keys, self.dim)
        )

    def _request_body(self, req: GatherRequest) -> np.ndarray:
        """The operator-specific request payload (appended after the
        runtime's ``[requester, slot, epoch]`` header by ``PE.submit``)."""
        return self._pad(req.keys)

    # -------------------------------------------------------- placement layer
    def plan_with(self, optimizer, workload) -> "object":
        """Price this service's pushdown against its pull baseline through
        a :class:`~repro.sharding.placement.PlacementOptimizer` (duck-typed
        — anything with a compatible ``plan``).  The gather pull side is
        one GET round trip *per row*."""
        n = max(len(workload), 1)
        rows = sum(len(b) for b in workload) / n
        kb = max(int(round(rows)), 1)
        return optimizer.plan(
            requester=self.cluster.client.name,
            executor=self.cluster.servers[0].name,
            operand_bytes=kb * self.dim * 4,
            result_bytes=kb * self.dim * 4,
            selectivity=1.0,
            request_payload_bytes=(3 + self.n_keys) * 4,
            op_name=self.op_name,
            return_name=self.return_name,
            return_header_bytes=3 * 4,
            n_requests=n,
            pull_messages=kb,
        )

    def _resolve_placement(self, placement, workload) -> str:
        """Resolve a placement directive to ``"pushdown"`` or ``"pull"``.

        Precedence: explicit argument > the cluster's flow-profile policy
        (``Cluster.set_placement`` / the ``placement`` knob) > pushdown.
        ``"auto"`` (or passing an optimizer instance) consults the cost
        model against the advertised capability vectors."""
        choice = placement if placement is not None else self.cluster.placement_policy
        if choice is None:
            return "pushdown"
        if not isinstance(choice, str):
            return self.plan_with(choice, workload).choice
        if choice == "auto":
            return self.plan_with(self._auto_optimizer(), workload).choice
        if choice not in ("pushdown", "pull"):
            raise ValueError(
                f"placement must be 'pushdown', 'pull', 'auto', or an "
                f"optimizer, got {choice!r}"
            )
        return choice

    def _auto_optimizer(self):
        opt = self.cluster.placement()
        if opt is not None:
            return opt
        from repro.sharding.placement import PlacementOptimizer

        return PlacementOptimizer(self.cluster)

    # ------------------------------------------------------------------- API
    def submit(
        self,
        keys: np.ndarray,
        tenant: str | None = None,
        express: bool = False,
        slot_quota: int = 0,
    ) -> int:
        """Queue one gather request (a batch of up to ``n_keys`` row ids).

        ``tenant``/``express``/``slot_quota`` thread the serving tier's
        per-tenant QoS down to the PE runtime: credit-budget attribution,
        control-lane drain priority, and CQ-slot admission quota."""
        keys = np.asarray(keys, np.int32)
        if not (1 <= len(keys) <= self.n_keys):
            raise ValueError(f"request must carry 1..{self.n_keys} keys")
        if keys.min() < 0 or keys.max() >= self.vocab:
            raise ValueError("key out of table range")
        req = GatherRequest(
            self._next_rid, keys, t_submit=time.perf_counter(),
            tenant=tenant, express=express, slot_quota=slot_quota,
        )
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _dead_peers(self) -> set[str]:
        """Peers the failure detector has declared dead, from any alive
        PE's point of view (the client's matters most: it submits)."""
        dead: set[str] = set()
        for pe in self.cluster.alive_pes():
            dead |= pe.progress.detector.dead
        return dead

    def _entry_server(self, req: GatherRequest, dead: set[str]) -> str | None:
        """Pick the request's entry server, skipping detector-dead owners.
        ``None`` means every shard the request touches is dead."""
        for key in req.keys:
            name = f"server{self.owner(key)}"
            if name not in dead:
                return name
        return None

    def _admit(self) -> int:
        admitted = 0
        dead = self._dead_peers() if self.cluster.client.reliability.enabled else set()
        held: list[GatherRequest] = []
        while self.queue:
            req = self.queue.popleft()
            entry = self._entry_server(req, dead)
            if entry is None:
                # every owning shard is dead: nothing can serve any key —
                # complete degraded with an all-invalid mask rather than
                # submitting into a void
                req.rows = np.zeros((len(req.keys), self.dim), np.float32)
                req.valid = np.zeros(len(req.keys), bool)
                req.degraded = True
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                admitted += 1
                continue
            fut = self.cluster.client.submit(
                entry,
                self.op_name,
                self._request_body(req),
                self.cq,
                expected=len(req.keys),
                express=req.express,
                tenant=req.tenant,
                slot_quota=req.slot_quota,
            )
            if fut is None:
                if self.cq.free_slots == 0:
                    # completion queue saturated: submit would-block (CQ
                    # backpressure admission) — requeue at the front and
                    # stop admitting until retirements free slots.
                    # In-flight requests are untouched; nothing raises
                    # mid-batch.
                    self.queue.appendleft(req)
                    break
                # slots remain but this request's tenant is at its CQ
                # quota: hold IT back and keep admitting other tenants —
                # one tenant's backlog must not head-of-line-block the rest
                held.append(req)
                continue
            fut.attempts = req.resubmits
            req.future = fut
            req.t_admit = time.perf_counter()
            self.active[fut.slot] = req
            admitted += 1
        for req in reversed(held):
            self.queue.appendleft(req)
        return admitted

    def _recover(self) -> int:
        """Deadline-driven recovery: each expired in-flight gather either
        degrades to a partial result (an owning shard is detector-dead —
        its positions can never arrive) or is resubmitted to the surviving
        owners (the loss was transient: a dropped one-sided RETURN write
        has no retransmit queue, so the service layer is the retry).
        Returns a progress count so recovery rounds read as progress."""
        rel = self.cluster.client.reliability
        if not rel.enabled:
            return 0
        actions = 0
        dead = self._dead_peers()
        exhausted: list[tuple[GatherRequest, list[str]]] = []
        for fut in self.cq.expired():
            req = self.active.get(fut.slot)
            if req is None:  # not one of ours (foreign submission)
                continue
            owners = {f"server{self.owner(k)}" for k in req.keys}
            del self.active[fut.slot]
            dead_owner = bool(owners & dead)
            if not dead_owner:
                req.resubmits += 1
            if dead_owner or req.resubmits > rel.retransmit_budget:
                # attributed: an owner died, or the budget is spent with
                # owners alive — either way degrade to whatever arrived
                # (result_partial preserves landed rows + validity mask;
                # cancelling first would discard them) and keep sweeping.
                # Raising here used to abandon every later expired future
                # mid-sweep, leaking its slot and stranding its request.
                rows, mask = fut.result_partial()
                req.future = None
                req.rows = rows[: len(req.keys)]
                req.valid = mask[: len(req.keys)]
                req.degraded = True
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                if not dead_owner:
                    exhausted.append((req, sorted(owners)))
                actions += 1
                continue
            # owners all believed alive, budget remains: transient loss —
            # resubmit (a dropped one-sided RETURN has no retransmit
            # queue, so the service layer is the retry)
            fut.cancel()
            req.future = None
            self.queue.appendleft(req)
            actions += 1
        if exhausted and self.strict_recovery:
            detail = "; ".join(
                f"rid={r.rid} owners={o} resubmits={r.resubmits}"
                for r, o in exhausted
            )
            raise TimeoutError(
                f"{len(exhausted)} gather(s) exceeded resubmit budget "
                f"({rel.retransmit_budget}) with owners alive: {detail}"
            )
        return actions

    def _retire(self) -> int:
        retired = 0
        for slot, req in list(self.active.items()):
            assert req.future is not None
            if req.future.done():
                req.rows = req.future.result()[: len(req.keys)]
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                del self.active[slot]
                retired += 1
        return retired

    def tick(self) -> int:
        """One scheduler round: admit -> flush -> poll every PE -> recover
        -> retire.  Returns a progress count (admissions + polled messages
        + recovery actions + retires)."""
        self.ticks += 1
        self.cq.advance()
        progress = self._admit()
        if self.batching:
            self.cluster.client.flush()
        for pe in self.cluster.alive_pes():
            progress += pe.poll()
        progress += self._recover()
        progress += self._retire()
        return progress

    def _outstanding_detail(self) -> str:
        """The attributed tail for the idle-timeout error: which requests
        are stuck, where, and for how long (satellite of the reliability
        layer — a bare timeout names nothing actionable)."""
        now = time.perf_counter()
        lines = []
        for slot, req in sorted(self.active.items()):
            fut = req.future
            arrived = self.cq._count(slot) if fut is not None else 0
            owners = sorted({f"server{self.owner(k)}" for k in req.keys})
            age_t = self.cq.ticks - fut.submit_tick if fut is not None else 0
            lines.append(
                f"  slot {slot}: rid={req.rid} arrived={arrived}/"
                f"{len(req.keys)} owners={owners} age={age_t} ticks "
                f"({now - req.t_submit:.3f}s) resubmits={req.resubmits}"
            )
        if self.queue:
            lines.append(f"  +{len(self.queue)} queued, never admitted")
        return "\n".join(lines)

    def run(self, max_rounds: int = 1_000_000) -> int:
        """Drive ticks until every queued/active request finished; returns
        the number of rounds.  Raises TimeoutError if the cluster goes idle
        with work outstanding (a lost frame — the fault-injection tests'
        detection path); under reliability, idleness is tolerated through
        the recovery horizon plus the CQ deadline before giving up, and
        the error enumerates every stuck request (slot, owners, ages)."""
        rel = self.cluster.client.reliability
        idle_limit = rel.idle_grace() + rel.future_deadline if rel.enabled else 2
        rounds = idle = 0
        while self.queue or self.active:
            if self.tick():
                idle = 0
            else:
                idle += 1
                if idle > idle_limit:
                    raise TimeoutError(
                        "service idle but requests outstanding:\n"
                        + self._outstanding_detail()
                    )
            rounds += 1
            if rounds > max_rounds:
                raise TimeoutError("max_rounds exceeded")
        return rounds

    # ------------------------------------------------- measured entry points
    def _invokes(self) -> int:
        return sum(pe.stats.invokes for pe in self.cluster.pes())

    def _report(
        self, results: list[np.ndarray], rounds: int, invokes0: int
    ) -> GatherReport:
        st = self.cluster.fabric.stats
        return GatherReport(
            results=results,
            rounds=rounds,
            invokes=self._invokes() - invokes0,
            **st.report_kwargs(),
        )

    def distribute_code(self, propagation: PropagationConfig) -> None:
        """Tree-publish the Gatherer to every alive server (code-only: no
        invoke) and mark every sender's cache for the covered peers, so the
        whole request stream — client key-frames and server-to-server
        FORWARDs alike — travels digest-only from the first request.
        Orphaned subtrees (dead mid-tree PE, dropped hop) are re-covered
        by the shared :meth:`repro.core.cluster.Cluster.distribute_code`."""
        self.cluster.distribute_code(self.op_name, propagation)

    def gather(
        self,
        key_batches: list[np.ndarray],
        batching: bool = False,
        dataplane: DataPlaneConfig | None = None,
        propagation: PropagationConfig | None = None,
        placement: object | None = None,
    ) -> GatherReport:
        """Submit a burst of requests, run to completion, report results in
        submission order plus wire/dispatch accounting for this run only.
        ``dataplane`` selects the partial-RETURN protocol: framed (default),
        zero-copy slab writes into the completion queue's registered region,
        or rendezvous descriptor + GET.  ``propagation`` pre-distributes the
        Gatherer down a spanning tree instead of letting each first contact
        push the code flat.  ``placement`` routes the burst: ``"pushdown"``
        (the X-RDMA path), ``"pull"`` (the per-row GET baseline),
        ``"auto"``/a :class:`~repro.sharding.placement.PlacementOptimizer`
        (cost-model choice); ``None`` defers to the cluster's policy."""
        if self._resolve_placement(placement, key_batches) == "pull":
            return self.gather_get(key_batches)
        self.cluster.fabric.stats.reset()
        invokes0 = self._invokes()
        n0 = len(self.finished)
        self.cluster.set_batching(batching)
        self.cluster.set_dataplane(dataplane)
        self.batching = batching
        if propagation is not None:
            self.distribute_code(propagation)
        try:
            rids = [self.submit(k) for k in key_batches]
            rounds = self.run()
        finally:
            self.batching = False
            self.cluster.set_batching(False)
            self.cluster.set_dataplane(None)
        # consume this burst's retirements: a long-running service must not
        # accumulate result rows for requests already handed back
        done_now, self.finished = self.finished[n0:], self.finished[:n0]
        by_rid = {r.rid: r for r in done_now}
        results = [by_rid[rid].rows for rid in rids]
        return self._report(results, rounds, invokes0)

    def gather_get(self, key_batches: list[np.ndarray]) -> GatherReport:
        """The move-data-to-compute baseline: one one-sided GET round trip
        per row, client does all the work (the gather sibling of GBPC)."""
        self.cluster.fabric.stats.reset()
        invokes0 = self._invokes()
        fabric = self.cluster.fabric
        client = self.cluster.client
        row_bytes = self.dim * 4
        results = []
        for keys in key_batches:
            keys = np.asarray(keys, np.int32)
            rows = np.empty((len(keys), self.dim), np.float32)
            for j, key in enumerate(keys):
                srv = self.owner(key)
                off = (int(key) - srv * self.rows_per_shard) * row_bytes
                data = fabric.get(
                    client.name, f"server{srv}", "embed_shard", off, row_bytes
                )
                rows[j] = np.frombuffer(data, np.float32)
            results.append(rows)
        return self._report(results, rounds=0, invokes0=invokes0)

    def oracle(self, key_batches: list[np.ndarray]) -> list[np.ndarray]:
        """Numpy take-based oracle for any gather implementation."""
        return [self.table[np.asarray(k, np.int32)] for k in key_batches]


class FilterShardService(EmbedShardService):
    """Predicate pushdown over the embedding-shard substrate.

    A request names a contiguous shard-aligned window ``[lo, lo+W)`` and a
    float32 threshold; the Filter ifunc evaluates ``rows[:, 0] > thresh``
    *next to the shard* and RETURNs only the survivors (a ragged payload —
    wire bytes scale with selectivity, the whole point of pushdown).  The
    result contract matches the oracle ``where(pred, window, 0)``: each
    surviving row lands at its original window position, dropped positions
    read zero.

    The pull baseline (:meth:`filter_pull`) fetches the window with one
    range GET and filters client-side — cheaper than pushdown exactly when
    the cost model says so (high selectivity, or an executor with a fat
    per-message overhead), which is what :meth:`filter`'s ``placement=``
    machinery decides.
    """

    op_name = "filter"
    return_name = "filter_return"

    def __init__(
        self,
        cluster: Cluster,
        vocab: int,
        dim: int,
        window: int = 16,
        max_slots: int = 64,
        seed: int = 0,
        table: np.ndarray | None = None,
        strict_recovery: bool = False,
    ) -> None:
        super().__init__(
            cluster, vocab, dim, n_keys=window, max_slots=max_slots,
            seed=seed, table=table, strict_recovery=strict_recovery,
        )
        self._thresh_bits = 0
        self._selectivity_hint = 1.0

    def _publish_ops(self) -> None:
        self.cluster.toolchain.publish(
            make_filter(
                self.rows_per_shard, self.cluster.n_servers, self.n_keys, self.dim
            )
        )
        self.cluster.toolchain.publish(
            make_filter_return(self.max_slots, self.n_keys, self.dim)
        )

    def _request_body(self, req: GatherRequest) -> np.ndarray:
        # [lo, thresh_bits]; PE.submit prepends [requester, slot, epoch]
        return np.array([int(req.keys[0]), self._thresh_bits], np.int32)

    def plan_with(self, optimizer, workload):
        w, d = self.n_keys, self.dim
        return optimizer.plan(
            requester=self.cluster.client.name,
            executor=self.cluster.servers[0].name,
            operand_bytes=w * d * 4,
            result_bytes=w * d * 4,
            selectivity=self._selectivity_hint,
            request_payload_bytes=5 * 4,  # [requester, slot, epoch, lo, thresh]
            op_name=self.op_name,
            return_name=self.return_name,
            return_header_bytes=(3 + w) * 4,  # [slot, epoch, evalmask] + spos
            n_requests=max(len(workload), 1),
            pull_messages=1,  # a window is one contiguous range GET
        )

    # -------------------------------------------------------------- workloads
    def windows(self, n_requests: int, seed: int = 0) -> np.ndarray:
        """``n_requests`` uniform-random shard-aligned window starts."""
        rng = np.random.default_rng(seed)
        w, rp = self.n_keys, self.rows_per_shard
        srv = rng.integers(0, self.cluster.n_servers, n_requests)
        off = rng.integers(0, rp - w + 1, n_requests)
        return (srv * rp + off).astype(np.int64)

    def thresh_for_selectivity(self, selectivity: float) -> np.float32:
        """The column-0 threshold whose pass rate is ``selectivity``."""
        q = np.quantile(self.table[:, 0].astype(np.float64), 1.0 - selectivity)
        return np.float32(q)

    def selectivity_of(self, thresh) -> float:
        return float(np.mean(self.table[:, 0] > np.float32(thresh)))

    def _window_keys(self, lo: int) -> np.ndarray:
        lo, w = int(lo), self.n_keys
        if not (0 <= lo and lo + w <= self.vocab):
            raise ValueError(f"window [{lo}, {lo + w}) outside the table")
        if self.owner(lo) != self.owner(lo + w - 1):
            raise ValueError(f"window [{lo}, {lo + w}) crosses a shard boundary")
        return np.arange(lo, lo + w, dtype=np.int32)

    # ------------------------------------------------------------ entrypoints
    def filter(
        self,
        los,
        thresh,
        batching: bool = False,
        dataplane: DataPlaneConfig | None = None,
        propagation: PropagationConfig | None = None,
        placement: object | None = None,
        selectivity: float | None = None,
    ) -> GatherReport:
        """Filter a burst of windows; one request per ``lo``.

        ``selectivity`` is the cost model's survivor-fraction estimate;
        by default it is computed exactly from the service's own table
        (deterministic, and what a real system's statistics catalog
        provides).  Placement resolution is as in :meth:`gather`."""
        thresh = np.float32(thresh)
        if selectivity is None:
            selectivity = self.selectivity_of(thresh)
        self._selectivity_hint = float(selectivity)
        if self._resolve_placement(placement, los) == "pull":
            return self.filter_pull(los, thresh)
        self._thresh_bits = int(
            np.frombuffer(np.float32(thresh).tobytes(), np.int32)[0]
        )
        batches = [self._window_keys(lo) for lo in los]
        return super().gather(
            batches, batching=batching, dataplane=dataplane,
            propagation=propagation, placement="pushdown",
        )

    def filter_pull(self, los, thresh) -> GatherReport:
        """Move-data-to-compute baseline: one range GET per window, the
        client evaluates the predicate after the whole operand crossed."""
        self.cluster.fabric.stats.reset()
        invokes0 = self._invokes()
        fabric, client = self.cluster.fabric, self.cluster.client
        w, d = self.n_keys, self.dim
        thresh = np.float32(thresh)
        results = []
        for lo in los:
            self._window_keys(lo)  # validate alignment like the pushdown path
            srv = self.owner(lo)
            off = (int(lo) - srv * self.rows_per_shard) * d * 4
            data = fabric.get(
                client.name, f"server{srv}", "embed_shard", off, w * d * 4
            )
            window = np.frombuffer(data, np.float32).reshape(w, d)
            results.append(
                np.where((window[:, 0] > thresh)[:, None], window, 0.0).astype(
                    np.float32
                )
            )
        return self._report(results, rounds=0, invokes0=invokes0)

    def oracle_filter(self, los, thresh) -> list[np.ndarray]:
        """Numpy oracle: ``where(col0 > thresh, window, 0)`` per window."""
        thresh = np.float32(thresh)
        out = []
        for lo in los:
            win = self.table[int(lo) : int(lo) + self.n_keys]
            out.append(
                np.where((win[:, 0] > thresh)[:, None], win, 0.0).astype(np.float32)
            )
        return out
