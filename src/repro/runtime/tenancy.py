"""Multi-tenant serving tier: per-tenant QoS over the PE fabric.

The serving shape: many tenants multiplex one embedding-shard substrate
(:class:`repro.runtime.embed_service.EmbedShardService`).  Without QoS a
single hot tenant saturates the shared completion queue and the per-peer
credit windows, and every other tenant's tail latency collapses with it.
The router maps each tenant's :class:`TenantClass` onto the three
isolation mechanisms the runtime already has:

* **lanes** — ``express`` tenants' frames carry :data:`FrameFlags.EXPRESS`
  and drain through the progress engine's control lane ahead of bulk data
  (PR 5's priority lanes, extended to tenant traffic);
* **credits** — ``credit_budget`` carves a per-tenant slice out of the
  sender's outgoing occupancy (the fabric's tenant ledger): a tenant over
  budget stalls its *own* (dst, tenant) wire lane while neighbours flow;
* **slots** — ``slot_quota`` caps the CQ slots a tenant may hold, reusing
  the ``submit -> None`` would-block contract for admission control.

Load shedding happens *above* the fabric: a tenant at ``queue_limit``
outstanding requests has new submissions refused at the router (``None``
rid) — a shed request never consumes a credit, a slot, or a wire byte, so
shedding is trivially exactly-once (nothing to cancel or dedup).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.verify import SandboxConfig
from repro.runtime.embed_service import EmbedShardService


@dataclass(frozen=True)
class TenantClass:
    """One tenant's QoS contract (all zeros = best-effort, no isolation).

    ``sandbox`` optionally declares the code-injection policy this tenant
    is willing to run under; the router merges every declaring class's
    policy with :meth:`SandboxConfig.strictest` and installs the result
    cluster-wide — the substrate is shared, so the fabric must enforce
    the strictest contract any tenant demanded."""

    name: str
    express: bool = False  # control-lane drain priority at the receivers
    credit_budget: int = 0  # outgoing payloads in flight (0 = unbudgeted)
    slot_quota: int = 0  # concurrent CQ slots (0 = uncapped)
    queue_limit: int = 0  # outstanding requests before shedding (0 = never)
    sandbox: SandboxConfig | None = None  # code-injection policy (None = none)


@dataclass
class TenantStats:
    """Per-tenant serving accounting (ticks are scheduler rounds)."""

    submitted: int = 0  # requests accepted by the router
    served: int = 0  # requests completed (degraded ones included)
    shed: int = 0  # requests refused at queue_limit (never entered fabric)
    degraded: int = 0  # served with a partial validity mask
    latencies: list = field(default_factory=list)  # ticks, submit -> done

    def percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies, np.float64), q))

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "served": self.served,
            "shed": self.shed,
            "degraded": self.degraded,
            "p50_ticks": self.percentile(50),
            "p95_ticks": self.percentile(95),
        }


class TenantRouter:
    """Request router multiplexing tenants onto one EmbedShardService.

    The router owns no queues of its own: admission control lives in the
    service (CQ backpressure + per-tenant slot quotas) and the wire layer
    (credit budgets), so the router's job is classification — stamp each
    request with its tenant's QoS — plus shedding and accounting.
    """

    def __init__(
        self, service: EmbedShardService, classes: "list[TenantClass]"
    ) -> None:
        self.service = service
        self.classes = {c.name: c for c in classes}
        if len(self.classes) != len(classes):
            raise ValueError("duplicate tenant class names")
        self.stats = {c.name: TenantStats() for c in classes}
        self._submit_tick: dict[int, int] = {}  # rid -> service tick
        self._rid_tenant: dict[int, str] = {}
        # install the credit carve-out on every PE's wire layer
        service.cluster.set_tenant_budgets(
            {c.name: c.credit_budget for c in classes if c.credit_budget}
        )
        # install the strictest declared code-injection policy cluster-wide
        boxes = [c.sandbox for c in classes if c.sandbox is not None]
        if boxes:
            service.cluster.set_sandbox(SandboxConfig.strictest(boxes))

    # ------------------------------------------------------------------ API
    def outstanding(self, tenant: str) -> int:
        """Requests accepted for ``tenant`` and not yet completed."""
        st = self.stats[tenant]
        return st.submitted - st.served

    def submit(self, tenant: str, keys: np.ndarray) -> int | None:
        """Route one gather request; returns its rid, or ``None`` when the
        tenant is at its queue limit and the request was shed (it never
        touched the fabric — exactly-once by construction)."""
        cls = self.classes[tenant]
        st = self.stats[tenant]
        if cls.queue_limit and self.outstanding(tenant) >= cls.queue_limit:
            st.shed += 1
            return None
        rid = self.service.submit(
            keys,
            tenant=tenant,
            express=cls.express,
            slot_quota=cls.slot_quota,
        )
        st.submitted += 1
        self._submit_tick[rid] = self.service.ticks
        self._rid_tenant[rid] = tenant
        return rid

    def _harvest(self) -> list:
        """Consume the service's finished list, attributing completions."""
        done, self.service.finished = self.service.finished, []
        for req in done:
            tenant = self._rid_tenant.pop(req.rid, None)
            if tenant is None:
                continue  # not router traffic (e.g. a warm-up gather)
            st = self.stats[tenant]
            st.served += 1
            if req.degraded:
                st.degraded += 1
            st.latencies.append(self.service.ticks - self._submit_tick.pop(req.rid))
        return done

    def tick(self) -> list:
        """One scheduler round; returns this round's completed requests."""
        self.service.tick()
        return self._harvest()

    def run(self, max_rounds: int = 1_000_000) -> int:
        """Drive ticks until every accepted request completed."""
        rounds = 0
        while self.service.queue or self.service.active:
            self.tick()
            rounds += 1
            if rounds > max_rounds:
                raise TimeoutError("tenant router exceeded max_rounds")
        self._harvest()
        return rounds

    def report(self) -> dict:
        return {name: st.as_dict() for name, st in sorted(self.stats.items())}


class RemoteEmbedClient:
    """Embedding rows as a service: the LM decode loop's token embeddings
    fetched through CQ-tracked gathers instead of a local table lookup.

    Owns a private cluster whose servers hold the (row-padded, f32)
    embedding table; :meth:`rows` chunks a token batch into ``n_keys``-row
    gathers and reassembles the result.  Rows travel bit-exactly (f32
    bit-cast through the int32 CQ words), so a decode stream fed by this
    client is bit-identical to the local-embed stream — the property
    tests/test_tenancy.py pins.
    """

    def __init__(
        self,
        embed_table: np.ndarray,
        n_servers: int = 2,
        n_keys: int = 8,
        max_slots: int = 16,
    ) -> None:
        from repro.core import Cluster

        table = np.asarray(embed_table, np.float32)
        self.vocab = table.shape[0]
        pad = (-self.vocab) % n_servers
        if pad:
            table = np.concatenate(
                [table, np.zeros((pad, table.shape[1]), np.float32)]
            )
        self.cluster = Cluster(n_servers)
        self.service = EmbedShardService(
            self.cluster,
            vocab=table.shape[0],
            dim=table.shape[1],
            n_keys=n_keys,
            max_slots=max_slots,
            table=table,
        )
        self.gathers = 0  # CQ-tracked gather requests issued

    def rows(self, ids: np.ndarray) -> np.ndarray:
        """Fetch embedding rows for ``ids`` (any shape) via the service."""
        ids = np.asarray(ids, np.int32)
        flat = ids.reshape(-1)
        n = self.service.n_keys
        batches = [flat[i : i + n] for i in range(0, len(flat), n)]
        report = self.service.gather(batches)
        self.gathers += len(batches)
        out = np.concatenate(report.results, axis=0)
        return out.reshape(*ids.shape, -1)
