"""Fault-tolerant training driver: the loop a real deployment runs.

Responsibilities:
  * jit the train step with the partition plan and run it over the pipeline
  * async-checkpoint every ``ckpt_every`` steps
  * watch health (heartbeats + straggler EWMA); on a fault, rebuild the
    mesh without the lost host (elastic), restore the latest committed
    checkpoint with the NEW shardings (leaves are stored unsharded), and
    resume from the restored step — the data pipeline is stateless given
    (step, shard), so batch k is bit-identical across the restart
  * inject faults deterministically for tests (``fail_at_step``)

On this container the "hosts" are simulated (the mesh is rebuilt over the
same CPU device set) but every code path — restore-with-reshard, step
replay, monitor triggers — is the production one.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore, latest_step, restore_state
from repro.data import DataConfig, TokenPipeline
from repro.models.zoo import build_params, make_train_step
from repro.optim import AdamW
from repro.runtime.monitor import HeartbeatMonitor, StepTimer

Params = dict[str, jax.Array]


@dataclass
class TrainReport:
    steps_run: int = 0
    restarts: int = 0
    losses: list[float] = field(default_factory=list)
    restored_steps: list[int] = field(default_factory=list)
    step_time_s: float = 0.0


class TrainDriver:
    def __init__(
        self,
        cfg,
        ckpt_dir: str | Path,
        opt: AdamW | None = None,
        mesh=None,
        data: DataConfig | None = None,
        ckpt_every: int = 10,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.opt = opt or AdamW(lr=1e-3)
        self.mesh = mesh
        self.ckpt = CheckpointStore(ckpt_dir, keep=3)
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.data = data or DataConfig(seq_len=128, global_batch=4, vocab=cfg.vocab)
        self.monitor = HeartbeatMonitor()
        self.timer = StepTimer()
        self._step_fn: Callable | None = None

    # ------------------------------------------------------------- plumbing
    def _shardings(self):
        if self.mesh is None:
            return None
        from repro.optim.adamw import OptState  # noqa: F401
        from repro.sharding.partition import state_shardings

        p_sds, axes = build_params(self.cfg, abstract=True)
        return state_shardings(p_sds, axes, self.mesh)

    def init_state(self) -> dict:
        params, _ = build_params(self.cfg, self.seed)
        return {
            "params": params,
            "opt": self.opt.init(params),
            "step": jnp.int32(0),
        }

    def _compile(self):
        sh = self._shardings()
        step = make_train_step(self.cfg, self.opt, mesh=self.mesh)
        if sh is None:
            self._step_fn = jax.jit(step, donate_argnums=(0,))
        else:
            self._step_fn = jax.jit(
                step, in_shardings=(sh, None), out_shardings=(sh, None),
                donate_argnums=(0,),
            )

    # ------------------------------------------------------------ recovery
    def restore_or_init(self) -> tuple[dict, int]:
        like = jax.eval_shape(self.init_state)
        step = latest_step(self.ckpt.path)
        if step is None:
            return self.init_state(), 0
        sh = self._shardings()
        state, step = restore_state(self.ckpt.path, like, shardings=sh)
        return state, step

    def handle_fault(self, lost_host: str | None = None) -> tuple[dict, int]:
        """The elastic path: (re)build mesh minus the lost host, restore the
        last committed checkpoint with the new shardings."""
        self.ckpt.wait()
        if lost_host:
            self.monitor.dead.add(lost_host)
        self._compile()  # re-lower against the (new) mesh
        return self.restore_or_init()

    # ----------------------------------------------------------------- run
    def run(
        self,
        n_steps: int,
        fail_at_step: int | None = None,
        max_restarts: int = 2,
    ) -> TrainReport:
        report = TrainReport()
        self._compile()
        state, start = self.restore_or_init()
        pipe = TokenPipeline(self.data)
        step = start
        failed_once = False
        t_loop = time.perf_counter()
        while step < n_steps:
            if fail_at_step is not None and step == fail_at_step and not failed_once:
                # simulated host loss mid-run (after ckpt step k, before k+1)
                failed_once = True
                report.restarts += 1
                state, step = self.handle_fault("host-7")
                report.restored_steps.append(step)
                continue
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            self.timer.record("host-0", time.perf_counter() - t0)
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}: {loss}")
            report.losses.append(loss)
            step += 1
            report.steps_run += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(state, step)
        self.ckpt.wait()
        self.ckpt.save_async(state, step)
        self.ckpt.wait()
        report.step_time_s = (time.perf_counter() - t_loop) / max(report.steps_run, 1)
        return report
