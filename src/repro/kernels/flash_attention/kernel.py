"""Blockwise online-softmax attention for TPU (Pallas).

Grid: (B, H, S/BQ, T/BK) — the T axis is innermost, so on TPU the kernel
revisits the same output block sequentially while streaming K/V blocks
HBM->VMEM; the running max/sum/accumulator live in VMEM scratch, which is
exactly the flash-attention recurrence mapped onto the Pallas TPU grid
model (sequential last axis + revisitable scratch).

GQA without materializing repeated K/V: the K/V BlockSpec index_map sends
query-head h to kv-head ``h // group`` — the MXU reads each K/V block
once per group from the same HBM tiles.

VMEM budget per step (bf16, BQ=BK=512, d=128):
  q (512x128x2) + k,v (2x512x128x2) + acc/m/l f32 (512x129x4) = ~0.66 MiB
well under the ~16 MiB/core VMEM of a v5e; BQ/BK are exposed for the
shape sweep in tests and the §Perf block-shape iteration.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, softcap: float | None, scale: float, bq: int, bk: int,
    nk: int, causal_off: int,
):
    """``causal_off = T - S``: when the query block is a suffix of the key
    sequence (prefill against prior context), query i may see keys up to
    i + causal_off (end-aligned causal masking, matching the oracle)."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = ki * bk
    # skip fully-masked blocks (strictly above the causal diagonal)
    run = (not causal) or (k_lo <= q_lo + causal_off + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows + causal_off, s, NEG)
        m_prev = m_scr[...]  # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)  # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "scale", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, d)
    k: jax.Array,  # (B, K, T, d)
    v: jax.Array,  # (B, K, T, d)
    causal: bool = True,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    g = h // kh
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nk = t // bk
    scale = 1.0 / math.sqrt(d) if scale is None else scale

    grid = (b, h, s // bq, nk)
    kern = functools.partial(
        _flash_kernel, causal=causal, softcap=softcap, scale=scale,
        bq=bq, bk=bk, nk=nk, causal_off=t - s,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
