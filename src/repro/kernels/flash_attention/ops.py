"""jit'd public wrapper: (B, S, H, d) layout adapter + CPU fallback.

The model keeps (B, S, H, d); the kernel wants (B, H, S, d) so the MXU
contraction dims are the last two.  On non-TPU backends the wrapper runs
the kernel in interpret mode (tests) or falls back to the jnp oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import flash_attention_ref


def attend_flash(
    q: jax.Array,  # (B, S, H, d) — model layout
    k: jax.Array,  # (B, T, K, d)
    v: jax.Array,
    causal: bool = True,
    softcap: float | None = None,
    bq: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = flash_attention(
        qt, kt, vt, causal=causal, softcap=softcap, bq=bq, bk=bk,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


def attend_ref(q, k, v, causal=True, softcap=None):
    out = flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, softcap=softcap,
    )
    return jnp.swapaxes(out, 1, 2)
