"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, softcap)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG = -2.0**30


def flash_attention_ref(
    q: jax.Array,  # (B, H, S, d)
    k: jax.Array,  # (B, K, T, d)
    v: jax.Array,  # (B, K, T, d)
    *,
    causal: bool = True,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    b, h, s, d = q.shape
    kh = k.shape[1]
    assert h % kh == 0
    g = h // kh
    scale = 1.0 / math.sqrt(d) if scale is None else scale
    qg = q.reshape(b, kh, g, s, d)
    logits = jnp.einsum(
        "bkgsd,bktd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), bool), k.shape[2] - s)
        logits = jnp.where(mask, logits, NEG)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", att.astype(v.dtype), v)
    return out.reshape(b, h, s, d)
