"""Pallas TPU kernels for the framework's compute hot-spots.

Each package holds ``kernel.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), optionally ``ops.py`` (the jit'd model-facing wrapper), and
``ref.py`` (the pure-jnp oracle every kernel is swept against in
tests/test_kernels.py, interpret=True on CPU):

  flash_attention/  blockwise online-softmax attention -- grid
                    (B, H, S/BQ, T/BK), GQA via K/V index_map, causal
                    block skipping, running max/sum/acc in VMEM scratch
  wkv6/             RWKV6 chunked linear attention -- the sequential
                    recurrence as 4 MXU matmuls per chunk, (M, M) state
                    in scratch across the sequential chunk axis
  ssm_scan/         chunked diagonal selective scan (Mamba) -- channel
                    tiles x chunk axis, (BD, N) state in scratch
  chase/            DAPC batched pointer chase -- the shard slice streams
                    through VMEM blocks, the frontier advances in
                    lock-step (DESIGN.md section 2 hardware adaptation)
  embed_lookup/     vocab-sharded lookup as a blocked one-hot MXU matmul
                    (the TPU gather idiom); partial rows feed the c2d psum
"""
