"""Pure-jnp oracle for the batched shard-local pointer chase."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chase_ref(
    table_shard: jax.Array,  # (N_loc,) int32 successors (global ids)
    frontier: jax.Array,  # (B,) int32 global addresses
    depth: jax.Array,  # (B,) int32 hops remaining per chase
    lo: int,  # first global id owned by this shard
    max_hops: int,
) -> tuple[jax.Array, jax.Array]:
    """Advance each chase while it stays inside [lo, lo+N_loc) and has
    depth left; returns (frontier', depth').  Mirrors the Chaser ifunc's
    lax.while_loop (core/xrdma.py) as a batched lock-step frontier."""
    n_loc = table_shard.shape[0]

    def hop(carry, _):
        f, d = carry
        loc = f - lo
        inside = (loc >= 0) & (loc < n_loc) & (d > 0)
        nxt = jnp.take(table_shard, jnp.clip(loc, 0, n_loc - 1))
        f = jnp.where(inside, nxt, f)
        d = jnp.where(inside, d - 1, d)
        return (f, d), None

    (f, d), _ = jax.lax.scan(hop, (frontier, depth), None, length=max_hops)
    return f, d
