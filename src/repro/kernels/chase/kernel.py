"""Batched shard-local pointer chase for TPU (Pallas).

The DAPC hot loop (paper Sec. IV-C): given the shard slice of the pointer
table and a frontier of B in-flight chases, advance every chase until it
leaves the shard or exhausts its depth.  One chase is a serial dependence
chain — intrinsic to the workload on ANY hardware (the paper's DPU cores
hit the same wall); throughput comes from B chases advancing in lock-step,
which is a (B,)-wide vectorized gather per hop.

TPU adaptation (DESIGN.md §2): the shard slice is tiled into VMEM blocks
along the grid's first axis; each grid step advances only the chases whose
frontier currently lands in its block (others pass through).  ``rounds``
sweeps the grid enough times that a chase hopping between blocks still
makes progress — callers size blocks so a shard slice is 1-4 blocks.

Frontier state (frontier, depth) lives in VMEM scratch across grid steps;
the block sweep axis is innermost-sequential, so this is a legal TPU
revisiting pattern (same discipline as the flash kernel's accumulator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chase_kernel(
    lo_ref, table_ref, f_ref, d_ref, fo_ref, do_ref, f_scr, d_scr,
    *, block: int, hops_per_visit: int, n_blocks: int, rounds: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        f_scr[...] = f_ref[...]
        d_scr[...] = d_ref[...]

    blk = step % n_blocks
    lo = lo_ref[0] + blk * block
    tab = table_ref[...]  # (block,) this block's slice of the shard

    def hop(_, carry):
        f, d = carry
        loc = f - lo
        inside = (loc >= 0) & (loc < block) & (d > 0)
        nxt = jnp.take(tab, jnp.clip(loc, 0, block - 1))
        f = jnp.where(inside, nxt, f)
        d = jnp.where(inside, d - 1, d)
        return f, d

    f, d = jax.lax.fori_loop(
        0, hops_per_visit, hop, (f_scr[...], d_scr[...])
    )
    f_scr[...] = f
    d_scr[...] = d

    @pl.when(step == n_blocks * rounds - 1)
    def _finish():
        fo_ref[...] = f_scr[...]
        do_ref[...] = d_scr[...]


@functools.partial(
    jax.jit, static_argnames=("block", "hops_per_visit", "rounds", "interpret")
)
def chase_shard(
    table_shard: jax.Array,  # (N_loc,) int32 successor table (global ids)
    frontier: jax.Array,  # (B,) int32 global addresses
    depth: jax.Array,  # (B,) int32 hops remaining
    lo: jax.Array,  # scalar int32: first global id of this shard
    block: int = 2048,
    hops_per_visit: int = 32,
    rounds: int = 4,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    n_loc = table_shard.shape[0]
    b = frontier.shape[0]
    block = min(block, n_loc)
    assert n_loc % block == 0, (n_loc, block)
    n_blocks = n_loc // block
    grid = (n_blocks * rounds,)
    kern = functools.partial(
        _chase_kernel, block=block, hops_per_visit=hops_per_visit,
        n_blocks=n_blocks, rounds=rounds,
    )
    f, d = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i % n_blocks,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b,), jnp.int32),
            pltpu.VMEM((b,), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32).reshape(1), table_shard, frontier, depth)
    return f, d
