"""Chunked diagonal selective scan (Mamba) for TPU (Pallas).

Same math as models/ssm.selective_scan_chunked (see the derivation there),
tiled for VMEM: grid (B, D/BD, T/C) with the chunk axis innermost-
sequential; each program owns a BD-channel slice (the recurrence is
independent per channel — the Mamba-TP fact) and carries its (BD, N)
state in scratch across chunk steps.

VMEM per step (BD=128, C=32, N=16, f32):
  x/dt/out (BD, C) x3 + b/c (C, N) x2 + cum/p/k/q (BD, C, N) x4
  + scores (BD, C, C) + state (BD, N)  =~  1.6 MiB — comfortable.

Numerics: per-chunk cumulative log-decay clamped at -60 (f32-safe); with
the Mamba dt init (softplus +(-4.6) bias => dt in [1e-3, 1e-1]) a chunk of
32 stays orders of magnitude inside that (DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_CLAMP = -60.0


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_out_ref, h_scr, *, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # (BD, C)
    dt = dt_ref[0].astype(jnp.float32)  # (BD, C)
    a = a_ref[0].astype(jnp.float32)  # (BD, N)
    b = b_ref[0].astype(jnp.float32)  # (C, N)
    c = c_ref[0].astype(jnp.float32)  # (C, N)
    bd, ch = x.shape

    log_a = dt[:, :, None] * a[:, None, :]  # (BD, C, N), negative
    cum = jnp.maximum(jnp.cumsum(log_a, axis=1), LOG_CLAMP)  # inclusive
    p = jnp.exp(cum)
    drive = (dt * x)[:, :, None] * b[None, :, :]  # (BD, C, N)
    k = drive * jnp.exp(-cum)
    q = c[None, :, :] * p  # (BD, C, N)

    s = jax.lax.dot_general(  # (BD, C, C) pairwise scores over the state dim
        q, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (bd, ch, ch), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bd, ch, ch), 2)
    y_intra = jnp.sum(jnp.where(cols <= rows, s, 0.0), axis=2)  # (BD, C)
    h = h_scr[...]
    y_inter = jnp.sum(q * h[:, None, :], axis=2)  # (BD, C)
    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    h_new = jnp.exp(cum[:, -1, :]) * (h + jnp.sum(k, axis=1))
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _finish():
        h_out_ref[0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def ssm_scan_chunked(
    x: jax.Array,  # (B, T, D)
    dt: jax.Array,
    a: jax.Array,  # (D, N)
    b: jax.Array,  # (B, T, N)
    c: jax.Array,
    chunk: int = 32,
    bd: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, T, D), final state (B, D, N)). Zero initial state
    (the decode path carries state through models/ssm instead)."""
    bsz, t, d = x.shape
    n = a.shape[-1]
    chunk = min(chunk, t)
    bd = min(bd, d)
    assert t % chunk == 0 and d % bd == 0, (t, chunk, d, bd)
    nc = t // chunk
    # kernel layout: channels-major (B, D, T)
    xt = jnp.swapaxes(x, 1, 2)
    dtt = jnp.swapaxes(dt, 1, 2)

    grid = (bsz, d // bd, nc)
    chan_spec = pl.BlockSpec((1, bd, chunk), lambda bi, di, ci: (bi, di, ci))
    seq_spec = pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0))
    y, h = pl.pallas_call(
        functools.partial(_ssm_kernel, nc=nc),
        grid=grid,
        in_specs=[
            chan_spec,
            chan_spec,
            pl.BlockSpec((1, bd, n), lambda bi, di, ci: (0, di, 0)),
            seq_spec,
            seq_spec,
        ],
        out_specs=[
            chan_spec,
            pl.BlockSpec((1, bd, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, d, t), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a[None], b, c)
    return jnp.swapaxes(y, 1, 2), h
