"""Pure-jnp oracle for the selective-scan kernel: the sequential recurrence
(mirror of models/ssm.selective_scan, kept self-contained)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan_ref(
    x: jax.Array,  # (B, T, D)
    dt: jax.Array,  # (B, T, D), positive
    a: jax.Array,  # (D, N), negative
    b: jax.Array,  # (B, T, N)
    c: jax.Array,  # (B, T, N)
    h0: jax.Array | None = None,  # (B, D, N)
) -> tuple[jax.Array, jax.Array]:
    bsz, t, d = x.shape
    n = a.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), f32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * a[None])
        drive = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = decay * h + drive
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    xs = tuple(jnp.moveaxis(v.astype(f32), 1, 0) for v in (x, dt, b, c))
    h, ys = lax.scan(step, h0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
