"""Pure-jnp oracle for the WKV6 kernel: the sequential recurrence.

Identical math to models/rwkv.wkv6_scan (kept separate so the kernel
package is self-contained):

    out_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def wkv6_ref(
    r: jax.Array,  # (B, T, H, M)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay factors in (0, 1)
    u: jax.Array,  # (H, M)
    state: jax.Array | None = None,  # (B, H, M, M)
) -> tuple[jax.Array, jax.Array]:
    b, t, h, m = r.shape
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((b, h, m, m), f32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        bonus = jnp.sum(r_t * u[None] * k_t, axis=-1, keepdims=True) * v_t
        out = jnp.einsum("bhm,bhmn->bhn", r_t, S) + bonus
        S = w_t[..., :, None] * S + k_t[..., :, None] * v_t[..., None, :]
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(f32), 1, 0) for a in (r, k, v, w))
    state, outs = lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state
