"""Chunked WKV6 linear attention for TPU (Pallas).

The sequential recurrence

    out_t = r_t^T (S_t + diag(u) k_t v_t^T);   S_{t+1} = diag(w_t) S_t + k_t v_t^T

is hostile to the MXU one step at a time.  The chunked reformulation
(the same one the official RWKV CUDA/Triton kernels use, re-tiled for
VMEM) turns a chunk of C steps into four MXU matmuls.  With
P_t = prod_{s<t} w_s (within-chunk cumulative decay, P_0 = 1):

    inter_t = (r_t . P_t) @ S_in                    — carry-in state
    intra_t = sum_{s<t} (r_t.P_t · k_s/P_{s+1}) v_s — strict-causal matmul
    bonus_t = (r_t · u · k_t) v_t                   — current token
    S_out   = diag(P_C) S_in + (K ⊙ P_C/P_{s+1})^T V

Grid: (B, H, T/C) with the chunk axis innermost-sequential; the (M, M)
state lives in VMEM scratch across chunk steps.  Cumulative decays are
computed in log space and clamped at -30 so the 1/P_{s+1} factors stay
finite in f32 (the standard trick; C <= 64 keeps the dynamic range tame).

VMEM per step (C=64, M=64): 4 x (C,M) f32 + (M,M) f32 + (C,C) f32
=~ 100 KiB — tiny; many (B,H) programs pipeline over it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# exp(+/-60) stays finite/normal in f32; the clamp only guards pathological
# all-channels-fully-decayed chunks (keep chunks <= 64 so the within-chunk
# log-decay range stays well inside it for realistic RWKV decays)
LOG_CLAMP = -60.0


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_scr, *, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # (C, M)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (M,)
    c = r.shape[0]

    logw = jnp.log(jnp.maximum(w, 1e-38))  # (C, M), <= 0
    cum = jnp.cumsum(logw, axis=0)  # log prod_{s<=t}
    log_p = jnp.maximum(cum - logw, LOG_CLAMP)  # log P_t = log prod_{s<t}
    log_pc = jnp.maximum(cum[-1:], LOG_CLAMP)  # log P_C (full chunk)

    r_dec = r * jnp.exp(log_p)  # r_t . P_t
    k_inv = k * jnp.exp(-jnp.maximum(cum, LOG_CLAMP))  # k_s / P_{s+1}
    k_rem = k * jnp.exp(log_pc - jnp.maximum(cum, LOG_CLAMP))  # k_s . P_C/P_{s+1}

    s_in = s_scr[...]  # (M, M)
    inter = jax.lax.dot_general(
        r_dec, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, M)
    a = jax.lax.dot_general(
        r_dec, k_inv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C) scores
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.where(cols < rows, a, 0.0)  # strictly causal
    intra = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    o_ref[0, 0] = (inter + intra + bonus).astype(o_ref.dtype)

    s_new = jnp.exp(log_pc).T * s_in + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _finish():
        s_out_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(
    r: jax.Array,  # (B, T, H, M)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, M)
    chunk: int = 16,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B, T, H, M), final state (B, H, M, M))."""
    b, t, h, m = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    # kernel layout: (B, H, T, M)
    rt, kt, vt, wt = (jnp.swapaxes(x, 1, 2) for x in (r, k, v, w))

    grid = (b, h, nc)
    spec = pl.BlockSpec((1, 1, chunk, m), lambda bi, hi, ci: (bi, hi, ci, 0))
    out, s_out = pl.pallas_call(
        functools.partial(_wkv6_kernel, nc=nc),
        grid=grid,
        in_specs=[
            spec, spec, spec, spec,
            pl.BlockSpec((1, m), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            spec,
            pl.BlockSpec((1, 1, m, m), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, m), r.dtype),
            jax.ShapeDtypeStruct((b, h, m, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return jnp.swapaxes(out, 1, 2), s_out
