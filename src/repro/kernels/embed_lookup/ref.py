"""Pure-jnp oracle for the vocab-sharded embedding lookup."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embed_lookup_ref(
    table_shard: jax.Array,  # (V_loc, D)
    ids: jax.Array,  # (N,) int32 global token ids
    lo: int,  # first vocab id owned by this shard
) -> jax.Array:
    """Partial lookup: rows for ids outside [lo, lo+V_loc) are zero (the
    cross-shard psum completes them — models/embedding.embed_c2d)."""
    v_loc = table_shard.shape[0]
    loc = ids - lo
    inside = (loc >= 0) & (loc < v_loc)
    out = jnp.take(table_shard, jnp.clip(loc, 0, v_loc - 1), axis=0)
    return jnp.where(inside[:, None], out, jnp.zeros((), out.dtype))
