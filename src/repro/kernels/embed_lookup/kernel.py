"""Vocab-sharded embedding lookup as a one-hot MXU matmul (Pallas).

The TPU has no fast arbitrary-gather from HBM, but its MXU eats
(tokens x vocab_tile) @ (vocab_tile x D) for breakfast: the classic TPU
embedding idiom is a *blocked one-hot matmul* — compare a token tile
against a vocab tile (producing a one-hot mask in VREGs, never in HBM)
and accumulate the matmul over vocab tiles.  Out-of-shard ids match no
tile and contribute zeros, which is exactly the partial-lookup semantics
the cross-shard psum needs (models/embedding.embed_c2d).

Grid: (tokens/BT, V_loc/BV) — vocab axis innermost-sequential, f32
accumulator in VMEM scratch.  VMEM per step (BT=256, BV=512, D<=8k bf16):
table tile 512xD + acc 256xD f32 =~ (D=6144) 6.3 + 6.3 MiB — fits; the
ops.py wrapper drops BV for very wide models.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _embed_kernel(lo_ref, ids_ref, tab_ref, o_ref, acc_scr, *, bv: int, nv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ids = ids_ref[...]  # (BT,)
    tab = tab_ref[...]  # (BV, D)
    base = lo_ref[0] + vi * bv
    # one-hot in registers: (BT, BV)
    cols = base + jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], bv), 1)
    onehot = (ids[:, None] == cols).astype(tab.dtype)
    acc_scr[...] += jax.lax.dot_general(
        onehot, tab, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(vi == nv - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def embed_lookup(
    table_shard: jax.Array,  # (V_loc, D)
    ids: jax.Array,  # (N,) int32 global ids
    lo: jax.Array,  # scalar int32 shard offset
    bt: int = 256,
    bv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    v_loc, d = table_shard.shape
    n = ids.shape[0]
    bt = min(bt, n)
    bv = min(bv, v_loc)
    assert n % bt == 0 and v_loc % bv == 0, (n, bt, v_loc, bv)
    nv = v_loc // bv
    grid = (n // bt, nv)
    return pl.pallas_call(
        functools.partial(_embed_kernel, bv=bv, nv=nv),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt,), lambda ti, vi: (ti,)),
            pl.BlockSpec((bv, d), lambda ti, vi: (vi, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ti, vi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), table_shard.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32).reshape(1), ids, table_shard)
